"""Chrome-trace export + programmatic trace analysis.

``export_chrome_trace`` writes the Trace Event Format JSON that Perfetto
(https://ui.perfetto.dev) and ``chrome://tracing`` load directly;
``validate_chrome_trace`` is the schema check CI and tests run against
every exported file.

``TraceAnalysis`` computes, **from spans alone**, the quantities the
pipeline's claims are made of:

  * per-stage wall breakdown (``wall_breakdown``): span count, summed
    duration, and *busy* time (union of intervals — concurrent spans of
    one stage counted once);
  * pairwise overlap (``overlap_seconds`` / ``hidden_fraction``): how
    much of stage A's time coincided with stage B. The fig19 "read time
    hidden under verification" claim is
    ``hidden_fraction("io.read", "io.wait")`` — read time not covered by
    executor stall time — and must agree with
    ``PipelineStats``' counter-derived ``overlap_efficiency``;
  * critical-path attribution (``critical_path``): every instant of the
    trace's wall clock attributed to exactly one stage (first active
    name in priority order), so "where did the time go" sums to the
    wall time instead of double-counting overlapped stages.

Name specs: everywhere a span name is accepted, ``"verify.*"`` matches
by prefix and a list/tuple unions several specs.
"""
from __future__ import annotations

import bisect
import json
import warnings
from typing import Iterable

_PHASES = frozenset("XiICbensftMOP")  # common Trace Event Format phases


def export_chrome_trace(tracer, path: str) -> str:
    """Write ``tracer``'s events as Chrome-trace JSON → ``path``.

    Warns when the tracer's rings wrapped (``tracer.dropped > 0``): the
    exported trace is then missing its oldest events and overlap/critical-
    path numbers derived from it undercount — raise ``ring_capacity``."""
    dropped = getattr(tracer, "dropped", 0)
    if dropped:
        warnings.warn(
            f"trace export is incomplete: {dropped} events were "
            f"overwritten by ring wrap-around; re-run with a larger "
            f"ring_capacity (enable_tracing(ring_capacity=...))",
            stacklevel=2)
    events = tracer.events()
    # thread-name metadata rows make the Perfetto timeline readable
    for tid, tname in sorted(tracer.thread_names().items()):
        events.append({"name": "thread_name", "ph": "M", "pid": events[0][
            "pid"] if events else 0, "tid": tid,
            "ts": 0, "args": {"name": tname}})
    doc = {"traceEvents": events, "displayTimeUnit": "ms"}
    with open(path, "w") as f:
        json.dump(doc, f)
    return path


def validate_chrome_trace(source) -> int:
    """Validate Trace Event Format structure; returns the event count.

    ``source`` is a path, a loaded trace dict (``{"traceEvents": [...]}``)
    or a bare event list. Raises ``ValueError`` on the first violation:
    missing required keys, unknown phase, non-numeric timestamps,
    negative durations, non-dict args, or async events without an id.
    """
    if isinstance(source, str):
        with open(source) as f:
            source = json.load(f)
    if isinstance(source, dict):
        events = source.get("traceEvents")
        if not isinstance(events, list):
            raise ValueError("trace JSON must carry a 'traceEvents' list")
    elif isinstance(source, list):
        events = source
    else:
        raise ValueError(f"unsupported trace source {type(source)!r}")
    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            raise ValueError(f"event {i}: not an object")
        for key in ("name", "ph", "pid", "tid", "ts"):
            if key not in ev:
                raise ValueError(f"event {i}: missing required key {key!r}")
        ph = ev["ph"]
        if ph not in _PHASES:
            raise ValueError(f"event {i}: unknown phase {ph!r}")
        if not isinstance(ev["ts"], (int, float)):
            raise ValueError(f"event {i}: non-numeric ts")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                raise ValueError(f"event {i}: 'X' event needs dur >= 0")
        if ph in ("b", "e", "n", "s", "f", "t") and "id" not in ev:
            raise ValueError(f"event {i}: async/flow event needs an id")
        if "args" in ev and not isinstance(ev["args"], dict):
            raise ValueError(f"event {i}: args must be an object")
    return len(events)


def _merge_intervals(iv: list[tuple[float, float]]
                     ) -> list[tuple[float, float]]:
    if not iv:
        return []
    iv = sorted(iv)
    out = [iv[0]]
    for s, e in iv[1:]:
        ls, le = out[-1]
        if s <= le:
            out[-1] = (ls, max(le, e))
        else:
            out.append((s, e))
    return out


def _intersect(a: list[tuple[float, float]],
               b: list[tuple[float, float]]) -> float:
    i = j = 0
    total = 0.0
    while i < len(a) and j < len(b):
        s = max(a[i][0], b[j][0])
        e = min(a[i][1], b[j][1])
        if e > s:
            total += e - s
        if a[i][1] <= b[j][1]:
            i += 1
        else:
            j += 1
    return total


class TraceAnalysis:
    """Stage timing analysis over exported Chrome-trace events.

    Accepts the event list ``Tracer.events()`` returns (or a loaded trace
    document). Only 'X' (span) events carry timing; instants/counters/
    async events are kept for ``async_pairs`` but excluded from the
    interval math. All returned times are **seconds**.
    """

    def __init__(self, events):
        if isinstance(events, dict):
            events = events.get("traceEvents", [])
        self.events = events
        self._spans: dict[str, list[tuple[float, float]]] = {}
        self._async: dict[tuple[str, int], list[dict]] = {}
        for ev in events:
            if ev.get("ph") == "X":
                s = ev["ts"] * 1e-6
                self._spans.setdefault(ev["name"], []).append(
                    (s, s + ev.get("dur", 0.0) * 1e-6))
            elif ev.get("ph") in ("b", "e"):
                self._async.setdefault((ev["name"], ev.get("id")),
                                       []).append(ev)
        self._unions: dict[tuple[str, ...], list] = {}

    # -- name specs -----------------------------------------------------------
    def names(self) -> list[str]:
        return sorted(self._spans)

    def _match(self, spec) -> tuple[str, ...]:
        """Resolve a name spec (exact, ``"prefix.*"``, or an iterable of
        specs) to the matching span names, as a canonical tuple."""
        if isinstance(spec, str):
            specs: Iterable[str] = (spec,)
        else:
            specs = tuple(spec)
        names: set[str] = set()
        for s in specs:
            if s.endswith("*"):
                pre = s[:-1]
                names.update(n for n in self._spans if n.startswith(pre))
            elif s in self._spans:
                names.add(s)
        return tuple(sorted(names))

    def _intervals(self, spec) -> list[tuple[float, float]]:
        out: list[tuple[float, float]] = []
        for n in self._match(spec):
            out.extend(self._spans[n])
        return out

    def _union(self, spec) -> list[tuple[float, float]]:
        key = self._match(spec)
        u = self._unions.get(key)
        if u is None:
            u = _merge_intervals([iv for n in key for iv in self._spans[n]])
            self._unions[key] = u
        return u

    # -- stage timing ---------------------------------------------------------
    def count(self, spec) -> int:
        return len(self._intervals(spec))

    def total_seconds(self, spec) -> float:
        """Summed span durations (concurrent spans double-count — this is
        the 'thread-seconds' a stage consumed, e.g. ``read_s``)."""
        return sum(e - s for s, e in self._intervals(spec))

    def busy_seconds(self, spec) -> float:
        """Union length: wall time during which ≥1 span of the stage was
        open (concurrency counted once)."""
        return sum(e - s for s, e in self._union(spec))

    def overlap_seconds(self, spec_a, spec_b) -> float:
        """Wall time during which both stages had an open span
        (|union(A) ∩ union(B)|)."""
        return _intersect(self._union(spec_a), self._union(spec_b))

    def overlap_fraction(self, spec_a, spec_b) -> float:
        """Fraction of stage A's total span time that coincided with
        stage B (0.0 when A recorded nothing)."""
        tot = self.total_seconds(spec_a)
        if tot <= 0:
            return 0.0
        return min(1.0, self.overlap_seconds(spec_a, spec_b) / tot)

    def hidden_fraction(self, spec_a, visible_spec) -> float:
        """Fraction of stage A's time NOT covered by ``visible_spec`` —
        the span-derived analogue of ``PipelineStats.overlap_efficiency``
        when called as ``hidden_fraction("io.read", "io.wait")``: read
        thread-seconds minus the wall time the executor was actually
        stalled, over read thread-seconds. 1.0 when A recorded nothing
        (matching the stats convention for ``read_s == 0``)."""
        tot = self.total_seconds(spec_a)
        if tot <= 0:
            return 1.0
        vis = self.overlap_seconds(spec_a, visible_spec)
        return max(0.0, tot - vis) / tot

    def wall_breakdown(self) -> dict[str, dict]:
        """Per-stage {count, total_s, busy_s}, all recorded span names."""
        return {n: {"count": len(iv),
                    "total_s": sum(e - s for s, e in iv),
                    "busy_s": self.busy_seconds(n)}
                for n, iv in sorted(self._spans.items())}

    def span_bounds(self) -> tuple[float, float]:
        iv = [b for ivs in self._spans.values() for b in ivs]
        if not iv:
            return (0.0, 0.0)
        return (min(s for s, _ in iv), max(e for _, e in iv))

    def critical_path(self, priorities: list | None = None
                      ) -> dict[str, float]:
        """Exclusive wall-time attribution over the trace's span extent.

        Each instant is attributed to the FIRST spec in ``priorities``
        with an open span at that time (default: every recorded name,
        most total time first); instants covered by no span are
        ``"idle"``. Values sum to the span extent — overlap never
        double-counts, which is what makes this a critical-path view:
        a stage only owns the time it was the reason the clock advanced.
        """
        if priorities is None:
            bd = self.wall_breakdown()
            priorities = sorted(bd, key=lambda n: -bd[n]["total_s"])
        unions = [(self._spec_label(p), self._union(p))
                  for p in priorities]
        t0, t1 = self.span_bounds()
        cuts = {t0, t1}
        for _, u in unions:
            for s, e in u:
                cuts.add(max(t0, min(s, t1)))
                cuts.add(max(t0, min(e, t1)))
        edges = sorted(cuts)
        out: dict[str, float] = {label: 0.0 for label, _ in unions}
        out["idle"] = 0.0
        starts = [(label, [s for s, _ in u], u) for label, u in unions]
        for a, b in zip(edges, edges[1:]):
            if b <= a:
                continue
            mid = (a + b) / 2
            owner = "idle"
            for label, ss, u in starts:
                k = bisect.bisect_right(ss, mid) - 1
                if k >= 0 and u[k][1] > mid:
                    owner = label
                    break
            out[owner] += b - a
        return out

    @staticmethod
    def _spec_label(spec) -> str:
        if isinstance(spec, str):
            return spec
        return "|".join(str(s) for s in spec)

    # -- async (request) events -----------------------------------------------
    def async_pairs(self, name: str) -> list[dict]:
        """Matched async begin/end pairs for ``name`` →
        [{id, start_s, end_s, duration_s, args}] (unterminated begins are
        skipped). Serving uses these for request lifetimes that span the
        submitter and drain threads."""
        out = []
        for (n, aid), evs in self._async.items():
            if n != name:
                continue
            begins = sorted((e for e in evs if e["ph"] == "b"),
                            key=lambda e: e["ts"])
            ends = sorted((e for e in evs if e["ph"] == "e"),
                          key=lambda e: e["ts"])
            for b, e in zip(begins, ends):
                args = dict(b.get("args") or {})
                args.update(e.get("args") or {})
                out.append({"id": aid, "start_s": b["ts"] * 1e-6,
                            "end_s": e["ts"] * 1e-6,
                            "duration_s": (e["ts"] - b["ts"]) * 1e-6,
                            "args": args})
        out.sort(key=lambda p: p["start_s"])
        return out

    # -- one-call summary -----------------------------------------------------
    def summary(self) -> dict:
        """JSON-ready digest: stage breakdown, critical path, and the
        pipeline's headline overlap figures (present stages only)."""
        t0, t1 = self.span_bounds()
        d = {
            "span_events": sum(len(v) for v in self._spans.values()),
            "stages": self.wall_breakdown(),
            "wall_s": t1 - t0,
            "critical_path_s": self.critical_path(),
        }
        if "io.read" in self._spans:
            d["read_hidden_fraction"] = self.hidden_fraction("io.read",
                                                             "io.wait")
            d["read_verify_overlap_s"] = self.overlap_seconds(
                "io.read", ("verify.*", "join.run"))
        return d
