"""repro.obs — span tracing, Perfetto export, and session metrics.

See ``src/repro/obs/README.md`` for the span taxonomy and metrics
naming conventions, and ``examples/quickstart.py`` for the two-line
"observe your join" recipe::

    from repro.obs import trace_session
    with trace_session() as tracer:
        index.self_join(epsilon=eps)
    tracer.export("join.trace.json")      # open in ui.perfetto.dev
    print(tracer.analysis().summary())
"""
from repro.obs.tracer import (
    NOOP_SPAN,
    Tracer,
    disable_tracing,
    enable_tracing,
    get_tracer,
    trace_session,
)
from repro.obs.export import (
    TraceAnalysis,
    export_chrome_trace,
    validate_chrome_trace,
)
from repro.obs.metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    log_bounds,
)
from repro.obs.live import (
    Alert,
    LiveCalibrator,
    LiveObserver,
    RollupWindow,
    Slo,
    SloMonitor,
    TimeSeries,
    default_serving_slos,
    merge_live_sections,
)
from repro.obs.webhook import WebhookSink

__all__ = [
    "NOOP_SPAN",
    "Tracer",
    "get_tracer",
    "enable_tracing",
    "disable_tracing",
    "trace_session",
    "TraceAnalysis",
    "export_chrome_trace",
    "validate_chrome_trace",
    "MetricsRegistry",
    "Counter",
    "Gauge",
    "Histogram",
    "log_bounds",
    "TimeSeries",
    "RollupWindow",
    "Slo",
    "SloMonitor",
    "Alert",
    "LiveCalibrator",
    "LiveObserver",
    "default_serving_slos",
    "merge_live_sections",
    "WebhookSink",
]
