"""Low-overhead, thread-aware span tracing for the DiskJoin pipeline.

Every pipeline stage (prefetch workers, the executor walk, verify
dispatch/collect, scheduler waves) records *spans* — named, nestable
wall-time intervals — into a per-thread ring buffer:

    with tracer.span("verify.flush", edges=E):
        ...

Design constraints, in order:

1. **Disabled must be ~free.** The tracer ships disabled; every
   instrumentation site pays one method call that returns a shared no-op
   context manager. No allocation, no clock read, no branch beyond
   ``if not self.enabled``. ``tests/test_obs.py`` asserts the measured
   per-call cost extrapolates to <1% of the fig19 workload's wall time.
2. **No cross-thread contention on the hot path.** Each thread appends
   to its own ring buffer (registered once per thread under a lock);
   record appends take no lock — the GIL serializes the two plain
   stores a ring append performs. Rings are fixed-capacity and overwrite
   oldest-first, so a forgotten enabled tracer degrades to bounded
   memory, never unbounded growth.
3. **One export surface.** ``export(path)`` writes Chrome-trace /
   Perfetto JSON (open at https://ui.perfetto.dev); ``analysis()``
   returns a programmatic ``TraceAnalysis`` over the same events, so
   overlap fractions and stage breakdowns are *derived from spans*
   rather than hand-maintained counters.

Event kinds (Chrome trace phases):
  span      'X'  complete event with duration (``span``/``complete``)
  instant   'i'  point event (``instant``)
  counter   'C'  sampled counter track (``counter``)
  async     'b'/'e'  cross-thread request lifetimes (``async_begin`` /
                 ``async_end``) — e.g. a serving request from submit on
                 the caller thread to completion on the drain thread.

A module-level *current tracer* (disabled by default) is what
instrumented components use when no tracer is passed explicitly:
``enable_tracing()`` swaps in a recording tracer, ``trace_session()``
scopes one to a ``with`` block and restores the previous on exit.
"""
from __future__ import annotations

import os
import threading
import time


class _Ring:
    """Fixed-capacity per-thread event ring; overwrites oldest on wrap."""

    __slots__ = ("buf", "cap", "i", "n", "dropped")

    def __init__(self, cap: int):
        self.buf: list = [None] * cap
        self.cap = cap
        self.i = 0        # next write position
        self.n = 0        # live entries
        self.dropped = 0  # overwritten (oldest-first) events

    def append(self, ev) -> None:
        self.buf[self.i] = ev
        self.i = (self.i + 1) % self.cap
        if self.n < self.cap:
            self.n += 1
        else:
            self.dropped += 1

    def snapshot(self) -> list:
        """Events oldest → newest (tolerates concurrent appends: a racing
        write may or may not be included, never torn — list stores are
        atomic reference assignments)."""
        if self.n < self.cap:
            return [e for e in self.buf[:self.n] if e is not None]
        out = self.buf[self.i:] + self.buf[:self.i]
        return [e for e in out if e is not None]


class _NoopSpan:
    """Shared do-nothing span: the disabled tracer's entire fast path."""

    __slots__ = ()

    def __enter__(self) -> "_NoopSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def set(self, **args) -> "_NoopSpan":
        return self


NOOP_SPAN = _NoopSpan()


class _Span:
    """A live recording span; records an 'X' event on ``__exit__``."""

    __slots__ = ("_tracer", "name", "args", "_t0")

    def __init__(self, tracer: "Tracer", name: str, args: dict | None):
        self._tracer = tracer
        self.name = name
        self.args = args

    def __enter__(self) -> "_Span":
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> bool:
        t1 = time.perf_counter()
        self._tracer._record(
            ("X", self.name, self._t0, t1 - self._t0, self.args, None))
        return False

    def set(self, **args) -> "_Span":
        """Attach/overwrite args on the span (appear in the exported
        event's ``args``)."""
        if self.args is None:
            self.args = args
        else:
            self.args.update(args)
        return self


class Tracer:
    """Thread-aware span/instant/counter recorder with ring storage.

    ``enabled=False`` constructs a permanent no-op tracer (every method
    returns immediately); the module-level default tracer is exactly
    that until ``enable_tracing()``.
    """

    def __init__(self, *, enabled: bool = True,
                 ring_capacity: int = 1 << 16):
        self.enabled = bool(enabled)
        self.ring_capacity = max(16, int(ring_capacity))
        self._epoch = time.perf_counter()
        self._tls = threading.local()
        self._rings: list[tuple[int, str, _Ring]] = []
        self._reg_lock = threading.Lock()
        # streaming consumers (repro.obs.live rollups): a usually-empty
        # tuple so the no-sink hot path pays one falsy check
        self._sinks: tuple = ()

    # -- recording (hot path) -------------------------------------------------
    def _ring(self) -> _Ring:
        r = getattr(self._tls, "ring", None)
        if r is None:
            r = _Ring(self.ring_capacity)
            self._tls.ring = r
            t = threading.current_thread()
            with self._reg_lock:
                self._rings.append((t.ident or 0, t.name, r))
        return r

    def _record(self, ev) -> None:
        self._ring().append(ev)
        if self._sinks:
            for fn in self._sinks:
                try:
                    fn(ev)
                except Exception:  # a broken consumer must not kill the
                    pass           # recording thread (serve path)

    # -- streaming consumers --------------------------------------------------
    def add_sink(self, fn) -> None:
        """Subscribe ``fn(event_tuple)`` to every recorded event — the
        raw ``(ph, name, ts, dur, args, async_id)`` tuples, called on the
        recording thread. Sinks must be cheap and never raise (exceptions
        are swallowed). ``repro.obs.live.TimeSeries.on_event`` is the
        canonical sink."""
        with self._reg_lock:
            self._sinks = self._sinks + (fn,)

    def remove_sink(self, fn) -> None:
        with self._reg_lock:
            self._sinks = tuple(s for s in self._sinks if s != fn)

    def span(self, name: str, **args):
        """Nestable wall-time span context manager (Chrome 'X' event)."""
        if not self.enabled:
            return NOOP_SPAN
        return _Span(self, name, args or None)

    def complete(self, name: str, t_start: float, duration_s: float,
                 **args) -> None:
        """Record a span from an interval the caller already timed
        (``t_start`` from ``time.perf_counter()``) — instrumentation that
        must agree *exactly* with an existing stats accumulator uses this
        so the trace and the counter see one measurement."""
        if not self.enabled:
            return
        self._record(("X", name, t_start, duration_s, args or None, None))

    def instant(self, name: str, **args) -> None:
        """Point-in-time event (Chrome 'i')."""
        if not self.enabled:
            return
        self._record(("i", name, time.perf_counter(), 0.0,
                      args or None, None))

    def counter(self, name: str, value, **args) -> None:
        """Sampled counter track (Chrome 'C'): one series per ``name``."""
        if not self.enabled:
            return
        a = {"value": value}
        if args:
            a.update(args)
        self._record(("C", name, time.perf_counter(), 0.0, a, None))

    def async_begin(self, name: str, async_id: int, **args) -> None:
        """Open a cross-thread async interval (Chrome 'b'); close it with
        ``async_end`` under the same (name, id) — from any thread."""
        if not self.enabled:
            return
        self._record(("b", name, time.perf_counter(), 0.0,
                      args or None, int(async_id)))

    def async_end(self, name: str, async_id: int, **args) -> None:
        if not self.enabled:
            return
        self._record(("e", name, time.perf_counter(), 0.0,
                      args or None, int(async_id)))

    # -- draining -------------------------------------------------------------
    def events(self) -> list[dict]:
        """All recorded events as Chrome-trace dicts (ts/dur in µs since
        the tracer epoch), sorted by timestamp."""
        pid = os.getpid()
        out: list[dict] = []
        with self._reg_lock:
            rings = list(self._rings)
        for tid, tname, ring in rings:
            for ph, name, ts, dur, args, aid in ring.snapshot():
                ev = {"name": name, "ph": ph, "pid": pid, "tid": tid,
                      "ts": (ts - self._epoch) * 1e6}
                if ph == "X":
                    ev["dur"] = dur * 1e6
                if ph in ("b", "e"):
                    ev["cat"] = "async"
                    ev["id"] = aid
                if args:
                    ev["args"] = dict(args)
                out.append(ev)
        out.sort(key=lambda e: e["ts"])
        return out

    def thread_names(self) -> dict[int, str]:
        with self._reg_lock:
            return {tid: tname for tid, tname, _ in self._rings}

    @property
    def dropped(self) -> int:
        """Events lost to ring wrap-around (size ``ring_capacity`` up)."""
        with self._reg_lock:
            return sum(r.dropped for _, _, r in self._rings)

    def ring_stats(self) -> dict:
        """Per-thread ring occupancy/capacity/drop counts plus totals —
        the session metrics surface exposes this so span drops are
        visible without holding the tracer object."""
        with self._reg_lock:
            rings = list(self._rings)
        threads = [{"tid": tid, "thread": tname, "occupancy": r.n,
                    "capacity": r.cap, "dropped": r.dropped}
                   for tid, tname, r in rings]
        return {"dropped": sum(t["dropped"] for t in threads),
                "events": sum(t["occupancy"] for t in threads),
                "ring_capacity": self.ring_capacity,
                "threads": threads}

    def clear(self) -> None:
        """Drop all recorded events (rings stay registered)."""
        with self._reg_lock:
            for _, _, r in self._rings:
                r.buf = [None] * r.cap
                r.i = r.n = 0

    # -- export / analysis (repro.obs.export) ---------------------------------
    def export(self, path: str) -> str:
        from repro.obs.export import export_chrome_trace
        return export_chrome_trace(self, path)

    def analysis(self) -> "TraceAnalysis":
        from repro.obs.export import TraceAnalysis
        return TraceAnalysis(self.events())


# -- module-level current tracer ----------------------------------------------
_DISABLED = Tracer(enabled=False)
_current: Tracer = _DISABLED
_current_lock = threading.Lock()


def get_tracer() -> Tracer:
    """The current tracer — a no-op unless tracing was enabled.
    Instrumented components resolve this when no tracer is injected."""
    return _current


def enable_tracing(ring_capacity: int = 1 << 16) -> Tracer:
    """Install (and return) a fresh recording tracer as the current one."""
    global _current
    with _current_lock:
        _current = Tracer(enabled=True, ring_capacity=ring_capacity)
        return _current


def disable_tracing() -> Tracer:
    """Swap the no-op tracer back in; returns the tracer that was active
    (its recorded events remain exportable)."""
    global _current
    with _current_lock:
        prev = _current
        _current = _DISABLED
        return prev


class trace_session:
    """``with trace_session() as tracer:`` — scope a recording tracer to
    a block; the previous current tracer is restored on exit and the
    session's tracer (with its events) is the bound value."""

    def __init__(self, ring_capacity: int = 1 << 16):
        self.ring_capacity = ring_capacity
        self.tracer: Tracer | None = None
        self._prev: Tracer | None = None

    def __enter__(self) -> Tracer:
        global _current
        with _current_lock:
            self._prev = _current
            self.tracer = Tracer(enabled=True,
                                 ring_capacity=self.ring_capacity)
            _current = self.tracer
        return self.tracer

    def __exit__(self, *exc) -> bool:
        global _current
        with _current_lock:
            _current = self._prev
        return False
