"""Sharded, async, atomic checkpointing with elastic restore."""
from repro.checkpoint.checkpoint import (CheckpointManager, restore_latest,
                                         save_checkpoint)

__all__ = ["CheckpointManager", "restore_latest", "save_checkpoint"]
