"""Checkpoint/restart (fault tolerance, DESIGN §6).

Layout per step:
    <dir>/step_000123.tmp/      — in-flight writes
        manifest.json           — step, mesh topology, tree structure, rng
        arr_00000.npy …         — one file per leaf (host-gathered)
    <dir>/step_000123/          — atomic rename commit

Properties:
  * **atomic**: a checkpoint is visible only after the directory rename; a
    crash mid-write leaves a ``.tmp`` that restore ignores and cleanup
    reaps. (The commit protocol lives in ``repro.ft.atomic`` and is
    shared with the join checkpointer.)
  * **async**: ``CheckpointManager(async_save=True)`` snapshots to host
    memory on the training thread, writes on a daemon thread — the step
    loop never blocks on disk.
  * **elastic restore**: leaves are saved host-complete; ``restore`` can
    re-shard onto a *different* mesh (chip count / topology change after a
    failure) by passing new shardings.
  * data-pipeline cursor + python RNG state ride in the manifest, so a
    restart resumes mid-epoch deterministically.
"""
from __future__ import annotations

import json
import os
import re
import shutil

import jax
import numpy as np

from repro.ft.atomic import AsyncCommitter, atomic_commit_dir, reap_tmp


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save_checkpoint(directory: str, step: int, tree, *,
                    extra: dict | None = None) -> str:
    """Blocking save. Returns the committed path."""
    leaves, treedef = _flatten(tree)

    def _write(tmp: str) -> None:
        dtypes = []
        for i, leaf in enumerate(leaves):
            arr = np.asarray(jax.device_get(leaf))
            dtypes.append(str(arr.dtype))
            if arr.dtype == np.dtype("bfloat16"):
                arr = arr.view(np.uint16)  # npy-safe container
            np.save(os.path.join(tmp, f"arr_{i:05d}.npy"), arr)
        manifest = {
            "step": step,
            "num_leaves": len(leaves),
            "treedef": str(treedef),
            "dtypes": dtypes,
            "extra": extra or {},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)

    return atomic_commit_dir(directory, f"step_{step:09d}", _write)


def list_checkpoints(directory: str) -> list[tuple[int, str]]:
    if not os.path.isdir(directory):
        return []
    out = []
    for d in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)", d)
        if m and os.path.exists(os.path.join(directory, d, "manifest.json")):
            out.append((int(m.group(1)), os.path.join(directory, d)))
    return sorted(out)


def restore_latest(directory: str, example_tree, *, shardings=None):
    """Restore newest checkpoint → (step, tree, extra) or None.

    ``example_tree`` fixes the pytree structure; ``shardings`` (optional
    matching tree of NamedShardings) re-shards onto the current mesh —
    elastic restart onto a different topology.
    """
    ckpts = list_checkpoints(directory)
    if not ckpts:
        return None
    step, path = ckpts[-1]
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    leaves, treedef = _flatten(example_tree)
    if manifest["num_leaves"] != len(leaves):
        raise ValueError(
            f"checkpoint has {manifest['num_leaves']} leaves, model expects "
            f"{len(leaves)} — architecture mismatch")
    out_leaves = []
    for i, (ex, dt) in enumerate(zip(leaves, manifest["dtypes"])):
        arr = np.load(os.path.join(path, f"arr_{i:05d}.npy"))
        if dt == "bfloat16":
            arr = arr.view(jax.numpy.bfloat16.dtype)
        out_leaves.append(arr)
    tree = jax.tree_util.tree_unflatten(treedef, out_leaves)
    if shardings is not None:
        tree = jax.tree_util.tree_map(
            lambda a, s: jax.device_put(a, s), tree, shardings)
    return step, tree, manifest.get("extra", {})


def cleanup(directory: str, keep: int = 3) -> None:
    ckpts = list_checkpoints(directory)
    for _, path in ckpts[:-keep]:
        shutil.rmtree(path, ignore_errors=True)
    reap_tmp(directory)


class CheckpointManager:
    """Double-buffered async writer with bounded queue (depth 1: a slow
    disk can delay at most one snapshot, never corrupt one). The worker
    thread and error-surfacing live in ``repro.ft.atomic.AsyncCommitter``."""

    def __init__(self, directory: str, keep: int = 3,
                 async_save: bool = True):
        self.directory = directory
        self.keep = keep
        self.async_save = async_save
        self._committer = (AsyncCommitter(name="train-ckpt")
                           if async_save else None)

    def _write(self, step: int, host_tree, extra: dict | None) -> None:
        save_checkpoint(self.directory, step, host_tree, extra=extra)
        cleanup(self.directory, self.keep)

    def save(self, step: int, tree, extra: dict | None = None) -> None:
        host_tree = jax.tree_util.tree_map(
            lambda x: np.asarray(jax.device_get(x)), tree)
        if self._committer is not None:
            # blocks if one write is in flight (depth-1 backpressure)
            self._committer.submit(
                lambda: self._write(step, host_tree, extra))
        else:
            self._write(step, host_tree, extra)

    def close(self) -> None:
        if self._committer is not None:
            self._committer.close()
