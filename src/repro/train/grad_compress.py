"""Gradient compression with error feedback (distributed-optimization trick).

int8 stochastic-free symmetric quantization per leaf with an error-feedback
accumulator: the quantization residual is carried into the next step, which
keeps SGD/Adam convergence (Karimireddy et al., 2019). At 1000+ nodes, the
cross-pod (DCN) gradient all-reduce is the scaling bottleneck; 4× smaller
payloads move the collective term directly.

Usage: ``AdamW(cfg, grad_transform=make_int8_compressor())``. The transform
runs *inside* the jitted train step — compression and decompression both
lower to a handful of elementwise HLO ops around the all-reduce.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def make_int8_compressor():
    """grad_transform(grads, error) → (decompressed grads, new error)."""

    def transform(grads, error):
        def one(g, e):
            g32 = g.astype(jnp.float32) + e
            q, scale = quantize_int8(g32)
            deq = dequantize_int8(q, scale)
            return deq.astype(g.dtype), g32 - deq

        flat_g, treedef = jax.tree_util.tree_flatten(grads)
        flat_e = treedef.flatten_up_to(error)
        out = [one(g, e) for g, e in zip(flat_g, flat_e)]
        return (treedef.unflatten([o[0] for o in out]),
                treedef.unflatten([o[1] for o in out]))

    return transform
