"""Training substrate: optimizer, loop, gradient compression."""
from repro.train.grad_compress import make_int8_compressor
from repro.train.optimizer import AdamW, AdamWConfig, lr_schedule
from repro.train.train_loop import TrainConfig, train

__all__ = ["AdamW", "AdamWConfig", "TrainConfig", "lr_schedule",
           "make_int8_compressor", "train"]
