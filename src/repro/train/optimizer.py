"""AdamW with f32 master stats, global-norm clipping, and hooks for
gradient compression — self-contained (no optax dependency).

State layout mirrors the param tree (mu, nu per leaf) so parameter
shardings propagate 1:1 to optimizer state; ZeRO-1/3 falls out of handing
``param_shardings(..., fsdp=True)`` to the state's out_shardings.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    learning_rate: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def lr_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup → cosine decay."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.learning_rate * warm * (cfg.min_lr_ratio
                                       + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


@dataclasses.dataclass
class AdamW:
    cfg: AdamWConfig
    # optional gradient transform (e.g. int8 compression w/ error feedback)
    grad_transform: Optional[Callable[[Any, Any], tuple[Any, Any]]] = None

    def init(self, params):
        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        state = {"mu": zeros,
                 "nu": jax.tree_util.tree_map(jnp.copy, zeros),
                 "step": jnp.zeros((), jnp.int32)}
        if self.grad_transform is not None:
            state["error"] = jax.tree_util.tree_map(jnp.copy, zeros)
        return state

    def update(self, grads, state, params):
        c = self.cfg
        step = state["step"] + 1
        if self.grad_transform is not None:
            grads, new_error = self.grad_transform(grads, state["error"])
        else:
            new_error = None
        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, c.clip_norm / (gnorm + 1e-9))
        lr = lr_schedule(c, step)
        b1t = 1 - c.b1 ** step.astype(jnp.float32)
        b2t = 1 - c.b2 ** step.astype(jnp.float32)

        def upd(g, mu, nu, p):
            g = g.astype(jnp.float32) * scale
            mu = c.b1 * mu + (1 - c.b1) * g
            nu = c.b2 * nu + (1 - c.b2) * g * g
            mhat = mu / b1t
            nhat = nu / b2t
            delta = mhat / (jnp.sqrt(nhat) + c.eps)
            delta = delta + c.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), \
                mu, nu

        flat_p, treedef = jax.tree_util.tree_flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_mu = treedef.flatten_up_to(state["mu"])
        flat_nu = treedef.flatten_up_to(state["nu"])
        out = [upd(g, m, n, p)
               for g, m, n, p in zip(flat_g, flat_mu, flat_nu, flat_p)]
        new_params = treedef.unflatten([o[0] for o in out])
        new_state = {"mu": treedef.unflatten([o[1] for o in out]),
                     "nu": treedef.unflatten([o[2] for o in out]),
                     "step": step}
        if new_error is not None:
            new_state["error"] = new_error
        return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
