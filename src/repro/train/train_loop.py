"""Training loop with checkpoint/restart, straggler telemetry, and elastic
re-meshing hooks (the end-to-end driver used by examples/train_lm.py).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import numpy as np

from repro.checkpoint import CheckpointManager, restore_latest
from repro.configs.base import ArchConfig
from repro.data.pipeline import PipelineConfig, TokenPipeline
from repro.dist import sharding as shd
from repro.models import build_model
from repro.runtime.straggler import StepTimer
from repro.train.optimizer import AdamW, AdamWConfig


@dataclasses.dataclass
class TrainConfig:
    steps: int = 100
    log_every: int = 10
    checkpoint_every: int = 50
    checkpoint_dir: Optional[str] = None
    seed: int = 0
    global_batch: int = 8
    seq_len: int = 128
    optimizer: AdamWConfig = dataclasses.field(default_factory=AdamWConfig)


def train(cfg: ArchConfig, tcfg: TrainConfig,
          mesh: Optional[jax.sharding.Mesh] = None,
          grad_transform=None,
          on_step: Optional[Callable[[int, dict], None]] = None) -> dict:
    """Train a (usually reduced) model end-to-end. Returns final metrics."""
    from repro.launch.steps import make_train_step  # lazy: avoids cycle
    bundle = build_model(cfg)
    opt = AdamW(tcfg.optimizer, grad_transform=grad_transform)
    step_fn = make_train_step(bundle, opt)

    pipeline = TokenPipeline(PipelineConfig(
        vocab=cfg.vocab, seq_len=tcfg.seq_len,
        global_batch=tcfg.global_batch, seed=tcfg.seed))

    rng = jax.random.PRNGKey(tcfg.seed)
    params = bundle.init(rng)
    opt_state = opt.init(params)
    start_step = 0

    manager = None
    if tcfg.checkpoint_dir:
        manager = CheckpointManager(tcfg.checkpoint_dir)
        restored = restore_latest(tcfg.checkpoint_dir,
                                  {"params": params, "opt": opt_state})
        if restored is not None:
            start_step, tree, extra = restored
            params, opt_state = tree["params"], tree["opt"]
            if "pipeline" in extra:
                pipeline.restore(extra["pipeline"])

    if mesh is not None:
        shd.set_mesh(mesh)
        p_shards = shd.param_shardings(params, mesh)
        params = jax.device_put(params, p_shards)
        jitted = jax.jit(step_fn, donate_argnums=(0, 1))
    else:
        jitted = jax.jit(step_fn, donate_argnums=(0, 1))

    timer = StepTimer()
    losses = []
    metrics = {}
    try:
        for step in range(start_step, tcfg.steps):
            batch = pipeline.batch_at(step)
            batch = {k: jax.numpy.asarray(v) for k, v in batch.items()}
            t0 = time.perf_counter()
            params, opt_state, metrics = jitted(params, opt_state, batch)
            loss = float(metrics["loss"])
            timer.record(time.perf_counter() - t0)
            losses.append(loss)
            if on_step is not None:
                on_step(step, {k: float(v) for k, v in metrics.items()})
            if step % tcfg.log_every == 0:
                print(f"step {step:5d} loss {loss:.4f} "
                      f"({timer.mean_ms:.0f} ms/step)")
            if manager and step and step % tcfg.checkpoint_every == 0:
                pipeline.step = step + 1
                manager.save(step + 1,
                             {"params": params, "opt": opt_state},
                             extra={"pipeline": pipeline.state()})
    finally:
        if manager:
            manager.close()
        shd.set_mesh(None)
    return {
        "final_loss": losses[-1] if losses else float("nan"),
        "loss_history": losses,
        "mean_step_ms": timer.mean_ms,
        "straggler_report": timer.report(),
    }
