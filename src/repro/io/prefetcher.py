"""Schedule-driven prefetching: perfect-future reads ahead of the executor.

The cache schedule (``repro.core.cache``) already fixes, offline, exactly
which accesses miss and in what order — the same offline knowledge the
paper uses for Belady eviction (§4.2). ``SchedulePrefetcher`` therefore
needs no prediction: an issue thread walks the schedule's miss sequence up
to ``lookahead`` loads ahead of the executor, takes a slab from the
``BufferPool`` (blocking when the pool is exhausted — backpressure), and
hands the read to a worker pool. The executor consumes loads in schedule
order via ``pop_next``; out-of-order *completion* is fine, consumption is
serialized by load index.

Multi-device stores (``StripedBucketedVectorStore``): the prefetcher keeps
one submission queue (worker pool of ``num_threads``) *per device*, so
lookahead saturates every device independently instead of serializing
through one shared pool — reads for device 1 never queue behind a full
device-0 queue.

Batched submission (io_uring-style): adjacent schedule misses landing on
the same device are submitted as ONE request (one task on that device's
queue). With ``coalesce``, batch members that are also disk-contiguous
(the bucketed writer lays extents out in schedule order, so
schedule-adjacent ⇒ disk-adjacent) collapse further into a single
sequential read, split into slabs on completion — one device round trip
instead of k.

Liveness: the executor evicts the scheduled victim (releasing its
residency pin) and flushes its pending verify batch (releasing batch pins)
*before* blocking on a load that has not been issued yet, so a pool with
at least (cache capacity + 1) slabs always frees a slab for the load the
executor is about to wait on. Batch extension only ever uses
``try_acquire`` — the issue thread never blocks while holding slabs beyond
the group's first.
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import ThreadPoolExecutor

import numpy as np

from repro.io.buffer_pool import BufferPool
from repro.io.pipeline import PipelineStats
from repro.io.retry import read_with_retry
from repro.obs import get_tracer

MAX_BATCH = 8  # reads per batched submission (io_uring SQ burst analogue)


class SchedulePrefetcher:
    """Issues the schedule's bucket loads ahead of time into pool slabs."""

    def __init__(self, store, actions, pool: BufferPool, *,
                 lookahead: int = 8, num_threads: int = 2,
                 stats: PipelineStats | None = None,
                 pad_value: float = 0.0,
                 batch_reads: bool = False, coalesce: bool = False,
                 max_batch: int = MAX_BATCH, close_pool: bool = True,
                 tracer=None, retries: int = 0,
                 retry_backoff_s: float = 0.005):
        """``close_pool=False`` marks ``pool`` as shared (owned by a
        ``DiskJoinIndex`` session, outliving this prefetcher): ``close()``
        then only wakes/cancels this prefetcher's waiters instead of
        closing the pool for every other consumer. ``retries`` tolerates
        that many transient read errors per run (capped exponential
        backoff, ``repro.io.retry``) before the error surfaces at
        ``pop_next``."""
        self.store = store
        self.pool = pool
        self.retries = max(0, int(retries))
        self.retry_backoff_s = float(retry_backoff_s)
        self.close_pool = bool(close_pool)
        self.lookahead = max(1, int(lookahead))
        self.stats = stats if stats is not None else PipelineStats()
        self.tracer = tracer if tracer is not None else get_tracer()
        self.pad_value = pad_value
        self.coalesce = bool(coalesce)
        self.batch_reads = bool(batch_reads) or self.coalesce
        self.max_batch = max(1, int(max_batch))
        self.num_devices = int(getattr(store, "num_devices", 1))
        self._device_of = (store.device_of if hasattr(store, "device_of")
                           else (lambda b: 0))
        self.stats.init_devices(self.num_devices)
        # the miss sequence: the only accesses that touch the disk.
        # ``actions`` is either a cache schedule ((bucket, is_hit, victim)
        # tuples — hits are skipped) or a plain bucket-id list (an ad-hoc
        # miss set, e.g. a serving wave's unioned probe set, every entry
        # of which is a read).
        self._loads = []
        for a in actions:
            if isinstance(a, (int, np.integer)):
                self._loads.append(int(a))
            elif not a[1]:
                self._loads.append(int(a[0]))
        self._results: dict[int, tuple[int, int] | BaseException] = {}
        self._issued = 0
        self._consumed = 0
        self._closed = False
        self._cond = threading.Condition()
        self._dev_inflight = [0] * self.num_devices
        # one submission queue per device: num_threads models the
        # device's usable queue depth; a striped store gets D independent
        # queues so no device idles behind another's backlog
        self._workers = [
            ThreadPoolExecutor(max_workers=max(1, int(num_threads)),
                               thread_name_prefix=f"diskjoin-io-d{d}")
            for d in range(self.num_devices)]
        self._issuer = threading.Thread(target=self._issue_loop,
                                        name="diskjoin-io-issue", daemon=True)
        self._issuer.start()

    # -- producer side -------------------------------------------------------
    def _issue_loop(self) -> None:
        try:
            loads = self._loads
            k = 0
            while k < len(loads):
                with self._cond:
                    while (k - self._consumed >= self.lookahead
                           and not self._closed):
                        self._cond.wait()
                    if self._closed:
                        return
                # backpressure: blocks when full; on a shared pool the wait
                # is cancellable so close() never strands this thread
                with self.tracer.span("io.acquire", bucket=loads[k]):
                    slot = self.pool.acquire(
                        cancelled=None if self.close_pool
                        else (lambda: self._closed))
                dev = self._device_of(loads[k])
                group = [(k, loads[k], slot)]
                if self.batch_reads:
                    self._extend_group(group, dev)
                with self._cond:
                    if self._closed:
                        for _, _, s in group:
                            self.pool.unpin(s)
                        return
                    self._issued = k + len(group)
                    depth = self._issued - self._consumed
                    self.stats.observe_depth(depth)
                    if self.tracer.enabled:
                        # rollup-visible queue depth (live dashboards)
                        self.tracer.counter("io.depth", value=depth)
                    self._dev_inflight[dev] += len(group)
                    self.stats.observe_device_depth(dev,
                                                    self._dev_inflight[dev])
                if len(group) > 1:
                    self.stats.add("batched_submissions", 1)
                    self.stats.add("batched_reads", len(group))
                # one submission, but each run is its own task: the device
                # serves batch entries concurrently (its queue depth =
                # io_threads), it does not serialize them — only
                # disk-contiguous runs collapse into a single read
                for run in self._partition_runs(group):
                    self._workers[dev].submit(self._read_run, dev, run)
                k += len(group)
        except BaseException as e:  # pool closed mid-acquire, etc.
            with self._cond:
                if not self._closed:
                    self._results[self._issued] = e
                    self._issued += 1
                    self._cond.notify_all()

    def _extend_group(self, group: list, dev: int) -> None:
        """Batch in the *adjacent* schedule misses that hit ``dev``.

        Stops at the first device change, the lookahead horizon, the batch
        cap, or pool exhaustion (``try_acquire`` never blocks — see module
        docstring liveness note)."""
        loads = self._loads
        j = group[0][0] + 1
        while j < len(loads) and len(group) < self.max_batch:
            if self._device_of(loads[j]) != dev:
                break
            with self._cond:
                if self._closed or j - self._consumed >= self.lookahead:
                    break
            slot = self.pool.try_acquire()
            if slot is None:
                break
            group.append((j, loads[j], slot))
            j += 1

    def _partition_runs(self, group: list) -> list[list]:
        """Split a batched submission into disk-contiguous runs (coalesced
        into one sequential read each) and singleton reads."""
        runs = [[group[0]]]
        for item in group[1:]:
            if (self.coalesce
                    and self.store.contiguous_after(runs[-1][-1][1],
                                                    item[1])):
                runs[-1].append(item)
            else:
                runs.append([item])
        return runs

    def _read_run(self, dev: int, run: list) -> None:
        t0 = time.perf_counter()
        try:
            if len(run) == 1:
                k, b, slot = run[0]
                n = read_with_retry(
                    lambda: self.store.read_bucket_into(
                        b, self.pool.vecs(slot), self.pool.ids(slot),
                        pad_value=self.pad_value),
                    retries=self.retries,
                    backoff_s=self.retry_backoff_s, stats=self.stats)
                results = [(k, (slot, n))]
            else:
                ns = read_with_retry(
                    lambda: self.store.read_run_into(
                        [b for _, b, _ in run],
                        [self.pool.vecs(s) for _, _, s in run],
                        [self.pool.ids(s) for _, _, s in run],
                        pad_value=self.pad_value),
                    retries=self.retries,
                    backoff_s=self.retry_backoff_s, stats=self.stats)
                self.stats.add("coalesced_reads", 1)
                self.stats.add("coalesced_buckets", len(run))
                results = [(k, (s, n))
                           for (k, _, s), n in zip(run, ns)]
        except BaseException as e:
            for _, _, slot in run:
                self.pool.unpin(slot)
            results = [(k, e) for k, _, _ in run]
        dt = time.perf_counter() - t0
        self.stats.add("read_s", dt)
        # complete() replays the exact interval read_s accumulated, so the
        # trace-derived hidden_fraction and overlap_efficiency see one
        # measurement, not two clocks
        self.tracer.complete("io.read", t0, dt, dev=dev,
                             buckets=[b for _, b, _ in run])
        self.stats.count_device_loads(dev, len(run))
        with self._cond:
            self._dev_inflight[dev] -= len(run)
            for k, res in results:
                self._results[k] = res
            self._cond.notify_all()

    # -- consumer side -------------------------------------------------------
    @property
    def next_issued(self) -> bool:
        """True iff the next load to consume has already been issued."""
        with self._cond:
            return self._issued > self._consumed

    def pop_next(self) -> tuple[int, int, int]:
        """Next scheduled load, in order → (bucket, slot, rows). Blocks
        (and counts a stall) if the read hasn't completed yet."""
        k = self._consumed
        if k >= len(self._loads):
            raise IndexError("prefetcher exhausted: schedule desync")
        with self._cond:
            if k not in self._results:
                self.stats.add("stalls", 1)
                while k not in self._results and not self._closed:
                    self._cond.wait()
                if self._closed and k not in self._results:
                    raise RuntimeError("prefetcher closed mid-run")
            res = self._results.pop(k)
            self._consumed = k + 1
            self.stats.add("loads", 1)
            self._cond.notify_all()
        if isinstance(res, BaseException):
            raise res
        slot, n = res
        return self._loads[k], slot, n

    def close(self) -> None:
        with self._cond:
            self._closed = True
            self._cond.notify_all()
        if self.close_pool:
            self.pool.close()
        else:
            self.pool.kick()  # shared pool stays open for other consumers
        self._issuer.join(timeout=10)
        for w in self._workers:
            w.shutdown(wait=True)
        # release any loads that completed but were never consumed
        with self._cond:
            for res in self._results.values():
                if not isinstance(res, BaseException):
                    self.pool.unpin(res[0])
            self._results.clear()


class PrefetchedBucketCache:
    """Executor-facing cache frontend backed by the prefetch pipeline.

    Mirrors the sync ``BucketCache`` surface (load/evict/rows/resident)
    plus explicit ``checkout``/``release`` pinning so pending verify
    batches keep their slabs alive across evictions.
    """

    def __init__(self, store, capacity_rows: int, actions, *,
                 lookahead: int = 8, pool_slabs: int | None = None,
                 num_threads: int = 2, pad_value: float = 0.0,
                 batch_reads: bool = False, coalesce: bool = False,
                 stats: PipelineStats | None = None,
                 pool: BufferPool | None = None, tracer=None,
                 retries: int = 0, retry_backoff_s: float = 0.005):
        """``pool``: an externally-owned (session) pool to read into —
        slab shape must match (``capacity_rows`` × ``store.dim``); it is
        left open by ``close()``. Without it a private pool of
        ``pool_slabs`` slabs is created and closed with the cache."""
        self.stats = stats if stats is not None else PipelineStats()
        self.capacity_rows = int(capacity_rows)
        if pool_slabs is None:
            raise ValueError("pool_slabs must be sized by the caller "
                             "(>= cache capacity + 1 for liveness)")
        self._owns_pool = pool is None
        if pool is None:
            pool = BufferPool(pool_slabs, capacity_rows, store.dim)
        elif (pool.capacity_rows != int(capacity_rows)
              or pool.dim != store.dim):
            raise ValueError(
                f"shared pool slabs are ({pool.capacity_rows}, {pool.dim}), "
                f"need ({capacity_rows}, {store.dim})")
        self.pool = pool
        self.stats.pool_slabs = pool.num_slabs
        self.stats.lookahead = int(lookahead)
        self.prefetcher = SchedulePrefetcher(
            store, actions, self.pool, lookahead=lookahead,
            num_threads=num_threads, stats=self.stats, pad_value=pad_value,
            batch_reads=batch_reads, coalesce=coalesce,
            close_pool=self._owns_pool, tracer=tracer,
            retries=retries, retry_backoff_s=retry_backoff_s)
        self._slots: dict[int, tuple[int, int]] = {}  # bucket -> (slot, rows)
        self.loads = 0

    def __contains__(self, b: int) -> bool:
        return b in self._slots

    @property
    def resident(self) -> int:
        return len(self._slots)

    @property
    def load_issued(self) -> bool:
        return self.prefetcher.next_issued

    def load(self, b: int) -> None:
        bucket, slot, n = self.prefetcher.pop_next()
        if bucket != b:
            raise AssertionError(
                f"prefetch desync: schedule wants {b}, stream has {bucket}")
        self._slots[b] = (slot, n)
        self.loads += 1

    def evict(self, b: int) -> None:
        ent = self._slots.pop(b, None)
        if ent is not None:
            self.pool.unpin(ent[0])  # drop the residency pin

    def rows(self, b: int) -> int:
        return self._slots[b][1]

    def checkout(self, b: int):
        """Pin bucket ``b``'s slab for a verify batch → (vecs, ids, n, slot)."""
        slot, n = self._slots[b]
        self.pool.pin(slot)
        return (self.pool.vecs(slot), self.pool.ids(slot), n, slot)

    def release(self, entry) -> None:
        self.pool.unpin(entry[3])

    def close(self) -> None:
        self.stats.max_slabs_in_use = self.pool.max_in_use
        self.stats.blocked_acquires = self.pool.blocked_acquires
        # drop the residency pins of buckets still resident at the end of
        # the schedule — on a shared (session) pool the slabs must return
        # to the free list for the next join/query, not leak
        for slot, _ in self._slots.values():
            self.pool.unpin(slot)
        self._slots.clear()
        self.prefetcher.close()
