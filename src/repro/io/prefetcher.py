"""Schedule-driven prefetching: perfect-future reads ahead of the executor.

The cache schedule (``repro.core.cache``) already fixes, offline, exactly
which accesses miss and in what order — the same offline knowledge the
paper uses for Belady eviction (§4.2). ``SchedulePrefetcher`` therefore
needs no prediction: an issue thread walks the schedule's miss sequence up
to ``lookahead`` loads ahead of the executor, takes a slab from the
``BufferPool`` (blocking when the pool is exhausted — backpressure), and
hands the read to a small worker pool. The executor consumes loads in
schedule order via ``pop_next``; out-of-order *completion* is fine,
consumption is serialized by load index.

Liveness: the executor evicts the scheduled victim (releasing its
residency pin) and flushes its pending verify batch (releasing batch pins)
*before* blocking on a load that has not been issued yet, so a pool with
at least (cache capacity + 1) slabs always frees a slab for the load the
executor is about to wait on.
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import ThreadPoolExecutor

import numpy as np

from repro.io.buffer_pool import BufferPool
from repro.io.pipeline import PipelineStats


class SchedulePrefetcher:
    """Issues the schedule's bucket loads ahead of time into pool slabs."""

    def __init__(self, store, actions, pool: BufferPool, *,
                 lookahead: int = 8, num_threads: int = 2,
                 stats: PipelineStats | None = None,
                 pad_value: float = 0.0):
        self.store = store
        self.pool = pool
        self.lookahead = max(1, int(lookahead))
        self.stats = stats if stats is not None else PipelineStats()
        self.pad_value = pad_value
        # the miss sequence: the only accesses that touch the disk
        self._loads = [int(b) for b, is_hit, _ in actions if not is_hit]
        self._results: dict[int, tuple[int, int] | BaseException] = {}
        self._issued = 0
        self._consumed = 0
        self._closed = False
        self._cond = threading.Condition()
        self._workers = ThreadPoolExecutor(
            max_workers=max(1, int(num_threads)),
            thread_name_prefix="diskjoin-io")
        self._issuer = threading.Thread(target=self._issue_loop,
                                        name="diskjoin-io-issue", daemon=True)
        self._issuer.start()

    # -- producer side -------------------------------------------------------
    def _issue_loop(self) -> None:
        try:
            for k, b in enumerate(self._loads):
                with self._cond:
                    while (k - self._consumed >= self.lookahead
                           and not self._closed):
                        self._cond.wait()
                    if self._closed:
                        return
                slot = self.pool.acquire()  # backpressure: blocks when full
                with self._cond:
                    if self._closed:
                        self.pool.unpin(slot)
                        return
                    self._issued = k + 1
                    self.stats.observe_depth(self._issued - self._consumed)
                self._workers.submit(self._read, k, b, slot)
        except BaseException as e:  # pool closed mid-acquire, etc.
            with self._cond:
                if not self._closed:
                    self._results[self._issued] = e
                    self._issued += 1
                    self._cond.notify_all()

    def _read(self, k: int, b: int, slot: int) -> None:
        t0 = time.perf_counter()
        try:
            n = self.store.read_bucket_into(
                b, self.pool.vecs(slot), self.pool.ids(slot),
                pad_value=self.pad_value)
            result: tuple[int, int] | BaseException = (slot, n)
        except BaseException as e:
            self.pool.unpin(slot)
            result = e
        self.stats.add("read_s", time.perf_counter() - t0)
        with self._cond:
            self._results[k] = result
            self._cond.notify_all()

    # -- consumer side -------------------------------------------------------
    @property
    def next_issued(self) -> bool:
        """True iff the next load to consume has already been issued."""
        with self._cond:
            return self._issued > self._consumed

    def pop_next(self) -> tuple[int, int, int]:
        """Next scheduled load, in order → (bucket, slot, rows). Blocks
        (and counts a stall) if the read hasn't completed yet."""
        k = self._consumed
        if k >= len(self._loads):
            raise IndexError("prefetcher exhausted: schedule desync")
        with self._cond:
            if k not in self._results:
                self.stats.add("stalls", 1)
                while k not in self._results and not self._closed:
                    self._cond.wait()
                if self._closed and k not in self._results:
                    raise RuntimeError("prefetcher closed mid-run")
            res = self._results.pop(k)
            self._consumed = k + 1
            self.stats.add("loads", 1)
            self._cond.notify_all()
        if isinstance(res, BaseException):
            raise res
        slot, n = res
        return self._loads[k], slot, n

    def close(self) -> None:
        with self._cond:
            self._closed = True
            self._cond.notify_all()
        self.pool.close()
        self._issuer.join(timeout=10)
        self._workers.shutdown(wait=True)
        # release any loads that completed but were never consumed
        with self._cond:
            for res in self._results.values():
                if not isinstance(res, BaseException):
                    self.pool.unpin(res[0])
            self._results.clear()


class PrefetchedBucketCache:
    """Executor-facing cache frontend backed by the prefetch pipeline.

    Mirrors the sync ``BucketCache`` surface (load/evict/rows/resident)
    plus explicit ``checkout``/``release`` pinning so pending verify
    batches keep their slabs alive across evictions.
    """

    def __init__(self, store, capacity_rows: int, actions, *,
                 lookahead: int = 8, pool_slabs: int | None = None,
                 num_threads: int = 2, pad_value: float = 0.0,
                 stats: PipelineStats | None = None):
        self.stats = stats if stats is not None else PipelineStats()
        self.capacity_rows = int(capacity_rows)
        if pool_slabs is None:
            raise ValueError("pool_slabs must be sized by the caller "
                             "(>= cache capacity + 1 for liveness)")
        self.pool = BufferPool(pool_slabs, capacity_rows, store.dim)
        self.stats.pool_slabs = pool_slabs
        self.stats.lookahead = int(lookahead)
        self.prefetcher = SchedulePrefetcher(
            store, actions, self.pool, lookahead=lookahead,
            num_threads=num_threads, stats=self.stats, pad_value=pad_value)
        self._slots: dict[int, tuple[int, int]] = {}  # bucket -> (slot, rows)
        self.loads = 0

    def __contains__(self, b: int) -> bool:
        return b in self._slots

    @property
    def resident(self) -> int:
        return len(self._slots)

    @property
    def load_issued(self) -> bool:
        return self.prefetcher.next_issued

    def load(self, b: int) -> None:
        bucket, slot, n = self.prefetcher.pop_next()
        if bucket != b:
            raise AssertionError(
                f"prefetch desync: schedule wants {b}, stream has {bucket}")
        self._slots[b] = (slot, n)
        self.loads += 1

    def evict(self, b: int) -> None:
        ent = self._slots.pop(b, None)
        if ent is not None:
            self.pool.unpin(ent[0])  # drop the residency pin

    def rows(self, b: int) -> int:
        return self._slots[b][1]

    def checkout(self, b: int):
        """Pin bucket ``b``'s slab for a verify batch → (vecs, ids, n, slot)."""
        slot, n = self._slots[b]
        self.pool.pin(slot)
        return (self.pool.vecs(slot), self.pool.ids(slot), n, slot)

    def release(self, entry) -> None:
        self.pool.unpin(entry[3])

    def close(self) -> None:
        self.stats.max_slabs_in_use = self.pool.max_in_use
        self.stats.blocked_acquires = self.pool.blocked_acquires
        self.prefetcher.close()
