"""Fixed slab pool with pin/unpin refcounting (no hot-path allocation).

All bucket I/O lands in one preallocated arena of ``num_slabs`` padded
``(capacity_rows, dim)`` float32 slabs (plus int64 id sidecars). A slab's
lifecycle:

    acquire() ── refcount 1 (cache residency) ──▶ in use
       pin()  ── +1 per pending verify batch reference
       unpin()── -1; at zero the slab returns to the free list

``acquire`` blocks when the pool is exhausted — this is the backpressure
that bounds the prefetcher's memory: it can run at most
(num_slabs - residents) bucket reads ahead of the executor.

Thread model: the prefetch issue thread acquires; worker threads fill the
slab arrays (each slot is owned by exactly one in-flight read); the
executor thread pins/unpins. All bookkeeping is under one condition lock.
"""
from __future__ import annotations

import threading

import numpy as np


class BufferPool:
    def __init__(self, num_slabs: int, capacity_rows: int, dim: int,
                 dtype=np.float32):
        if num_slabs < 1:
            raise ValueError("pool needs at least one slab")
        self.num_slabs = int(num_slabs)
        self.capacity_rows = int(capacity_rows)
        self.dim = int(dim)
        self._vecs = np.empty((num_slabs, capacity_rows, dim), dtype)
        self._ids = np.empty((num_slabs, capacity_rows), np.int64)
        self._refs = [0] * num_slabs
        self._free = list(range(num_slabs - 1, -1, -1))
        self._cond = threading.Condition()
        self._closed = False
        self.max_in_use = 0
        self.acquires = 0
        self.blocked_acquires = 0  # acquires that had to wait (backpressure)

    # -- slab memory ---------------------------------------------------------
    def vecs(self, slot: int) -> np.ndarray:
        return self._vecs[slot]

    def ids(self, slot: int) -> np.ndarray:
        return self._ids[slot]

    @property
    def nbytes(self) -> int:
        return self._vecs.nbytes + self._ids.nbytes

    @property
    def in_use(self) -> int:
        with self._cond:
            return self.num_slabs - len(self._free)

    def refcount(self, slot: int) -> int:
        with self._cond:
            return self._refs[slot]

    # -- lifecycle -----------------------------------------------------------
    def _take_free(self) -> int:
        """Pop a free slab at refcount 1 (caller holds the lock)."""
        slot = self._free.pop()
        self._refs[slot] = 1
        self.max_in_use = max(self.max_in_use,
                              self.num_slabs - len(self._free))
        return slot

    def acquire(self, timeout: float | None = None,
                cancelled=None) -> int:
        """Take a free slab (refcount 1). Blocks while the pool is empty.

        ``acquires`` counts attempts (blocking and non-blocking alike).
        ``cancelled`` (zero-arg callable) supports shared pools that outlive
        any one consumer: a waiter polls it and aborts with ``RuntimeError``
        when it returns True, instead of requiring the whole pool to close.
        """
        with self._cond:
            self.acquires += 1
            if not self._free:
                self.blocked_acquires += 1
            while not self._free and not self._closed:
                if cancelled is not None:
                    if cancelled():
                        raise RuntimeError("buffer pool acquire cancelled")
                    self._cond.wait(timeout=0.05)
                elif not self._cond.wait(timeout=timeout):
                    raise TimeoutError("buffer pool exhausted "
                                       f"({self.num_slabs} slabs, all pinned)")
            if self._closed:
                raise RuntimeError("buffer pool closed")
            return self._take_free()

    def try_acquire(self) -> int | None:
        """Non-blocking acquire: a free slab (refcount 1) or None.

        The batched-submission path uses this for every slab after a
        group's first — extending a batch must never block while holding
        already-acquired slabs (liveness), so exhaustion simply caps the
        batch size instead of waiting.
        """
        with self._cond:
            self.acquires += 1
            if self._closed or not self._free:
                return None
            return self._take_free()

    def pin(self, slot: int) -> None:
        """Add a reference; only legal on a live (already-acquired) slab."""
        with self._cond:
            if self._refs[slot] <= 0:
                raise RuntimeError(f"pin on free slab {slot}")
            self._refs[slot] += 1

    def unpin(self, slot: int) -> None:
        """Drop a reference; at zero the slab becomes reusable."""
        with self._cond:
            if self._refs[slot] <= 0:
                raise RuntimeError(f"unpin under-run on slab {slot}")
            self._refs[slot] -= 1
            if self._refs[slot] == 0:
                self._free.append(slot)
                self._cond.notify_all()

    def kick(self) -> None:
        """Wake blocked acquirers so they re-check their ``cancelled``
        callback — used when a consumer of a *shared* pool shuts down
        without closing the pool for everyone else."""
        with self._cond:
            self._cond.notify_all()

    def close(self) -> None:
        """Unblock any waiter; further acquires fail."""
        with self._cond:
            self._closed = True
            self._cond.notify_all()
