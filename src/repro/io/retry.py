"""Transient read-error handling: capped exponential backoff + retry.

SSDs (and striped arrays of them) throw transient ``OSError``/``IOError``
— a timeout, a momentary EIO on one stripe. Before this module, any such
error aborted the whole join; now every store read path (sync
``BucketCache``, ``SchedulePrefetcher`` workers, the index's pooled
query reads, the distributed join's padded reads) retries up to
``JoinConfig.io_retries`` times, sleeping ``backoff_s · 2^attempt``
(capped) between attempts. Exhausted retries re-raise the last error —
permanent failures still fail fast, just not on the first blip.

Counters land in ``PipelineStats``: ``io_read_errors`` counts failed
attempts, ``io_retries`` counts re-issues (retries ≤ errors: the final
attempt of a permanent failure errors without a retry following it).
"""
from __future__ import annotations

import time

BACKOFF_CAP_MULT = 50  # cap the exponential at 50× the base backoff


def read_with_retry(fn, *, retries: int, backoff_s: float, stats=None):
    """Call ``fn()``, retrying transient ``OSError`` up to ``retries``
    times with capped exponential backoff. Returns ``fn``'s result or
    re-raises the last error."""
    attempt = 0
    while True:
        try:
            return fn()
        except OSError:
            if stats is not None:
                stats.add("io_read_errors", 1)
            if attempt >= retries:
                raise
            delay = min(backoff_s * (2 ** attempt),
                        backoff_s * BACKOFF_CAP_MULT)
            if delay > 0:
                time.sleep(delay)
            attempt += 1
            if stats is not None:
                stats.add("io_retries", 1)
