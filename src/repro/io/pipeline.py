"""Pipeline telemetry: how much disk time the prefetcher actually hid.

``io_wait_s`` is the executor-observed stall (time blocked on a load that
wasn't ready); ``read_s`` is the wall time workers spent inside reads. A
perfect pipeline has io_wait → 0 with read_s unchanged, so

    overlap_efficiency = hidden / read_s,  hidden = max(0, read_s - io_wait)

(1.0 = all I/O behind compute, 0.0 = fully serial — the sync executor by
construction). Queue depth and backpressure counters come from the
prefetcher/pool and size the lookahead/pool knobs.

Multi-device additions (striped stores): per-device load counts and max
in-flight depth (is every device's queue actually kept full?), plus the
batched-submission and coalesced-read counters of the io_uring-style
submission path (how many per-read round trips the batching saved).
"""
from __future__ import annotations

import dataclasses
import threading


@dataclasses.dataclass
class PipelineStats:
    io_wait_s: float = 0.0      # executor stall waiting on loads
    compute_s: float = 0.0      # executor time in verify/flush
    read_s: float = 0.0         # worker wall time inside bucket reads
    loads: int = 0              # loads consumed by the executor
    stalls: int = 0             # loads that were not ready when needed
    flush_on_stall: int = 0     # early batch flushes to release pins
    max_queue_depth: int = 0    # max issued-not-consumed loads
    pool_slabs: int = 0
    max_slabs_in_use: int = 0
    blocked_acquires: int = 0   # pool-exhaustion backpressure events
    lookahead: int = 0
    num_devices: int = 1        # submission queues (striped store stripes)
    batched_submissions: int = 0  # submissions carrying > 1 read
    batched_reads: int = 0        # reads that rode in a batched submission
    coalesced_reads: int = 0      # merged sequential reads performed
    coalesced_buckets: int = 0    # buckets served by coalesced reads
    # transient-fault handling (repro.io.retry): a flaky SSD read is
    # retried with capped exponential backoff instead of aborting the join
    io_read_errors: int = 0       # read attempts that raised OSError
    io_retries: int = 0           # re-issued reads (≤ errors; last may fail)
    # serving fast restart (repro.ft): buckets pre-faulted into the warm
    # cache from a residency snapshot by DiskJoinIndex.open(warm_start=True)
    warm_prefaults: int = 0
    residency_snapshots: int = 0  # periodic in-run snapshots submitted
    # online point-query serving (DiskJoinIndex.query — shares this stats
    # object with the batch joins of the same index session)
    queries: int = 0              # point queries answered
    query_reads: int = 0          # bucket reads issued for queries (pooled)
    query_warm_hits: int = 0      # query candidates served from warm slabs
    query_fallback_reads: int = 0  # unpooled reads (pool fully contended)
    # wave-batched serving (repro.serve.QueryScheduler): concurrent
    # queries probing the same bucket in one wave share a single read
    waves: int = 0                   # scheduler waves executed
    shared_probe_reads: int = 0      # distinct buckets probed per wave, summed
    reads_saved_by_sharing: int = 0  # per-query probe refs minus distinct
    deadline_drops: int = 0          # requests expired & dropped (any stage)
    deadline_drops_midwave: int = 0  # subset dropped after the wave's reads
    midwave_skipped_reads: int = 0   # reads skipped: all probers cancelled
    admission_rejects: int = 0       # requests refused by estimate admission
    # cost-based planner (repro.plan): decisions taken per session
    plans: int = 0                   # batch-join plans emitted
    wave_plans: int = 0              # serving-wave plans emitted
    planned_pair_cap: int = 0        # last planned compaction capacity
    # device verify pipeline (repro.compute, compute_mode="device"):
    # slab H2D transfers are bounded by cache residencies, not edge count
    h2d_transfers: int = 0           # host→device transfers issued
    h2d_bytes: int = 0               # bytes moved host→device
    d2h_bytes: int = 0               # result bytes fetched device→host
    h2d_transfers_saved: int = 0     # operand refs served device-resident
    device_slab_hits: int = 0        # lookups hitting the device slab pool
    device_batches: int = 0          # double-buffered kernel dispatches
    device_compact_overflows: int = 0  # batches re-compacted at larger cap
    d2h_overlap_s: float = 0.0       # host work overlapped with the kernel
    device_loads: list = dataclasses.field(default_factory=list)
    device_depth_max: list = dataclasses.field(default_factory=list)

    def __post_init__(self) -> None:
        self._lock = threading.Lock()

    def add(self, field: str, amount) -> None:
        with self._lock:
            setattr(self, field, getattr(self, field) + amount)

    def observe_depth(self, depth: int) -> None:
        with self._lock:
            self.max_queue_depth = max(self.max_queue_depth, depth)

    # -- per-device telemetry -------------------------------------------------
    def init_devices(self, num_devices: int) -> None:
        with self._lock:
            self.num_devices = int(num_devices)
            self.device_loads = [0] * self.num_devices
            self.device_depth_max = [0] * self.num_devices

    def observe_device_depth(self, dev: int, depth: int) -> None:
        with self._lock:
            self.device_depth_max[dev] = max(self.device_depth_max[dev],
                                             depth)

    def count_device_loads(self, dev: int, n: int) -> None:
        with self._lock:
            self.device_loads[dev] += n

    @property
    def overlap_efficiency(self) -> float:
        if self.read_s <= 0:
            return 1.0
        return max(0.0, self.read_s - self.io_wait_s) / self.read_s

    # configuration/high-water fields: a point-in-time reading, not an
    # accumulating counter — reported as-is by snapshot_since
    GAUGE_FIELDS = frozenset({
        "pool_slabs", "lookahead", "num_devices", "max_queue_depth",
        "max_slabs_in_use", "blocked_acquires", "device_depth_max",
        "planned_pair_cap",
    })

    def snapshot(self) -> dict:
        with self._lock:
            d = {}
            for f in dataclasses.fields(PipelineStats):
                v = getattr(self, f.name)
                d[f.name] = list(v) if isinstance(v, list) else v
        d["overlap_efficiency"] = (
            max(0.0, d["read_s"] - d["io_wait_s"]) / d["read_s"]
            if d["read_s"] > 0 else 1.0)
        return d

    @staticmethod
    def merge(snapshots: list[dict]) -> dict:
        """Aggregate ``snapshot()`` dicts from several sessions (one per
        router shard) into one rollup. Naive summation is wrong for two
        classes of fields: the list-valued per-device telemetry
        (``device_loads``/``device_depth_max``) — shards own *distinct*
        devices, so lists concatenate and ``num_devices`` sums rather
        than zip-adding lists of unequal length — and the gauges, which
        are point-in-time readings where only the max across shards is
        meaningful. Additive counters sum; ``overlap_efficiency`` is
        recomputed from the merged read/wait totals, never averaged.
        """
        out: dict = {}
        for f in dataclasses.fields(PipelineStats):
            k = f.name
            if k in ("device_loads", "device_depth_max"):
                out[k] = [x for s in snapshots for x in s.get(k, [])]
            elif k == "num_devices":
                out[k] = sum(s.get(k, 0) for s in snapshots)
            elif k in PipelineStats.GAUGE_FIELDS:
                out[k] = max((s.get(k, 0) for s in snapshots), default=0)
            else:
                out[k] = sum(s.get(k, 0) for s in snapshots)
        out["overlap_efficiency"] = (
            max(0.0, out["read_s"] - out["io_wait_s"]) / out["read_s"]
            if out["read_s"] > 0 else 1.0)
        return out

    def snapshot_since(self, base: dict) -> dict:
        """Per-run view on a long-lived (session) stats object: additive
        counters are diffed against ``base`` (a prior ``snapshot()``);
        gauges report their current reading. Activity from concurrent
        consumers of the same session (e.g. online queries during a batch
        join) lands in the window it happened in."""
        cur = self.snapshot()
        out = {}
        for k, v in cur.items():
            b = base.get(k)
            if k in self.GAUGE_FIELDS or k == "overlap_efficiency" \
                    or b is None:
                out[k] = v
            elif isinstance(v, list):
                # per-device lists are RESET by init_devices each time a
                # prefetcher attaches, so the current list already is the
                # latest run's telemetry; subtracting a base captured
                # before that reset (e.g. holding the build/layout pass's
                # loads) would undercount whichever devices were busy then
                out[k] = v
            else:
                out[k] = v - b
        out["overlap_efficiency"] = (
            max(0.0, out["read_s"] - out["io_wait_s"]) / out["read_s"]
            if out["read_s"] > 0 else 1.0)
        return out
