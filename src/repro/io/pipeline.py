"""Pipeline telemetry: how much disk time the prefetcher actually hid.

``io_wait_s`` is the executor-observed stall (time blocked on a load that
wasn't ready); ``read_s`` is the wall time workers spent inside reads. A
perfect pipeline has io_wait → 0 with read_s unchanged, so

    overlap_efficiency = hidden / read_s,  hidden = max(0, read_s - io_wait)

(1.0 = all I/O behind compute, 0.0 = fully serial — the sync executor by
construction). Queue depth and backpressure counters come from the
prefetcher/pool and size the lookahead/pool knobs.
"""
from __future__ import annotations

import dataclasses
import threading


@dataclasses.dataclass
class PipelineStats:
    io_wait_s: float = 0.0      # executor stall waiting on loads
    compute_s: float = 0.0      # executor time in verify/flush
    read_s: float = 0.0         # worker wall time inside bucket reads
    loads: int = 0              # loads consumed by the executor
    stalls: int = 0             # loads that were not ready when needed
    flush_on_stall: int = 0     # early batch flushes to release pins
    max_queue_depth: int = 0    # max issued-not-consumed loads
    pool_slabs: int = 0
    max_slabs_in_use: int = 0
    blocked_acquires: int = 0   # pool-exhaustion backpressure events
    lookahead: int = 0

    def __post_init__(self) -> None:
        self._lock = threading.Lock()

    def add(self, field: str, amount) -> None:
        with self._lock:
            setattr(self, field, getattr(self, field) + amount)

    def observe_depth(self, depth: int) -> None:
        with self._lock:
            self.max_queue_depth = max(self.max_queue_depth, depth)

    @property
    def overlap_efficiency(self) -> float:
        if self.read_s <= 0:
            return 1.0
        return max(0.0, self.read_s - self.io_wait_s) / self.read_s

    def snapshot(self) -> dict:
        with self._lock:
            d = {f.name: getattr(self, f.name)
                 for f in dataclasses.fields(PipelineStats)}
        d["overlap_efficiency"] = (
            max(0.0, d["read_s"] - d["io_wait_s"]) / d["read_s"]
            if d["read_s"] > 0 else 1.0)
        return d
