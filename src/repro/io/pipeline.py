"""Pipeline telemetry: how much disk time the prefetcher actually hid.

``io_wait_s`` is the executor-observed stall (time blocked on a load that
wasn't ready); ``read_s`` is the wall time workers spent inside reads. A
perfect pipeline has io_wait → 0 with read_s unchanged, so

    overlap_efficiency = hidden / read_s,  hidden = max(0, read_s - io_wait)

(1.0 = all I/O behind compute, 0.0 = fully serial — the sync executor by
construction). Queue depth and backpressure counters come from the
prefetcher/pool and size the lookahead/pool knobs.

Multi-device additions (striped stores): per-device load counts and max
in-flight depth (is every device's queue actually kept full?), plus the
batched-submission and coalesced-read counters of the io_uring-style
submission path (how many per-read round trips the batching saved).
"""
from __future__ import annotations

import dataclasses
import threading


@dataclasses.dataclass
class PipelineStats:
    io_wait_s: float = 0.0      # executor stall waiting on loads
    compute_s: float = 0.0      # executor time in verify/flush
    read_s: float = 0.0         # worker wall time inside bucket reads
    loads: int = 0              # loads consumed by the executor
    stalls: int = 0             # loads that were not ready when needed
    flush_on_stall: int = 0     # early batch flushes to release pins
    max_queue_depth: int = 0    # max issued-not-consumed loads
    pool_slabs: int = 0
    max_slabs_in_use: int = 0
    blocked_acquires: int = 0   # pool-exhaustion backpressure events
    lookahead: int = 0
    num_devices: int = 1        # submission queues (striped store stripes)
    batched_submissions: int = 0  # submissions carrying > 1 read
    batched_reads: int = 0        # reads that rode in a batched submission
    coalesced_reads: int = 0      # merged sequential reads performed
    coalesced_buckets: int = 0    # buckets served by coalesced reads
    device_loads: list = dataclasses.field(default_factory=list)
    device_depth_max: list = dataclasses.field(default_factory=list)

    def __post_init__(self) -> None:
        self._lock = threading.Lock()

    def add(self, field: str, amount) -> None:
        with self._lock:
            setattr(self, field, getattr(self, field) + amount)

    def observe_depth(self, depth: int) -> None:
        with self._lock:
            self.max_queue_depth = max(self.max_queue_depth, depth)

    # -- per-device telemetry -------------------------------------------------
    def init_devices(self, num_devices: int) -> None:
        with self._lock:
            self.num_devices = int(num_devices)
            self.device_loads = [0] * self.num_devices
            self.device_depth_max = [0] * self.num_devices

    def observe_device_depth(self, dev: int, depth: int) -> None:
        with self._lock:
            self.device_depth_max[dev] = max(self.device_depth_max[dev],
                                             depth)

    def count_device_loads(self, dev: int, n: int) -> None:
        with self._lock:
            self.device_loads[dev] += n

    @property
    def overlap_efficiency(self) -> float:
        if self.read_s <= 0:
            return 1.0
        return max(0.0, self.read_s - self.io_wait_s) / self.read_s

    def snapshot(self) -> dict:
        with self._lock:
            d = {}
            for f in dataclasses.fields(PipelineStats):
                v = getattr(self, f.name)
                d[f.name] = list(v) if isinstance(v, list) else v
        d["overlap_efficiency"] = (
            max(0.0, d["read_s"] - d["io_wait_s"]) / d["read_s"]
            if d["read_s"] > 0 else 1.0)
        return d
