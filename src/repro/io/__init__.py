"""Async prefetching I/O subsystem (paper §4 + §5 beyond-paper extension).

The orchestration phase materializes the *exact* future access sequence and
cache schedule, so the executor has perfect knowledge of every bucket read
it will ever issue. This package exploits that to overlap SSD reads with
Pallas verification:

  ``buffer_pool``  — fixed pool of pre-padded bucket slabs with pin/unpin
                     refcounting (no hot-path allocation; pending verify
                     batches keep evicted slabs alive via pins).
  ``prefetcher``   — ``SchedulePrefetcher`` walks the precomputed cache
                     schedule ahead of the executor with a bounded
                     lookahead window, issuing reads with pool-exhaustion
                     backpressure on one submission queue *per device*
                     (striped stores), batching adjacent same-device
                     misses into single submissions and coalescing
                     disk-contiguous ones into single sequential reads.
                     ``PrefetchedBucketCache`` is the executor-facing
                     frontend (same surface as the sync ``BucketCache``).
  ``pipeline``     — ``PipelineStats``: io_wait/compute split, overlap
                     efficiency, queue depth, per-device depth/loads and
                     batched/coalesced-read counters; surfaced in
                     ``JoinResult.timings`` / ``io_stats["pipeline"]``.

Selected via ``JoinConfig.io_mode`` ("sync" | "prefetch"); result pair
sets are identical in both modes by construction — only *when* reads
happen changes, never which bytes end up in front of the kernel.
"""
from repro.io.buffer_pool import BufferPool
from repro.io.pipeline import PipelineStats
from repro.io.prefetcher import PrefetchedBucketCache, SchedulePrefetcher

__all__ = ["BufferPool", "PipelineStats", "PrefetchedBucketCache",
           "SchedulePrefetcher"]
