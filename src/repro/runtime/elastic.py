"""Elastic scaling: heartbeat registry + mesh (re)planning (DESIGN §6).

On hardware loss the job must restart on fewer hosts without human input:
  1. ``HeartbeatRegistry`` notices missing heartbeats (federated in real
     deployments; in-process here, same policy),
  2. ``plan_mesh`` picks the largest (pod, data, model) factorization the
     surviving chip count and the architecture's divisibility admit,
  3. the checkpoint layer restores host-complete arrays re-sharded onto the
     new mesh (``restore_latest(..., shardings=new)``) and the data
     pipeline rescales its host slices (pure function of step — no
     coordination needed).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional


@dataclasses.dataclass
class MeshPlan:
    pod: int
    data: int
    model: int

    @property
    def chips(self) -> int:
        return self.pod * self.data * self.model

    def axes(self) -> tuple:
        if self.pod > 1:
            return ((self.pod, self.data, self.model),
                    ("pod", "data", "model"))
        return ((self.data, self.model), ("data", "model"))


def plan_mesh(available_chips: int, *, global_batch: int,
              preferred_model: int = 16, chips_per_pod: int = 256,
              min_model: int = 1) -> Optional[MeshPlan]:
    """Largest usable (pod, data, model) plan under divisibility rules.

    model: largest power of two ≤ preferred_model (TP degree stays MXU
    friendly); data: what's left per pod, must divide global_batch.
    """
    if available_chips < 1:
        return None
    pods = max(1, available_chips // chips_per_pod)
    best: Optional[MeshPlan] = None
    while pods >= 1:
        chips = min(available_chips, pods * chips_per_pod) // pods
        model = 1 << (preferred_model.bit_length() - 1)
        while model >= min_model:
            # largest data ≤ chips/model with batch divisibility — a
            # non-divisor chip count shrinks data rather than failing
            data = chips // model
            while data >= 1 and (global_batch % (data * pods)) != 0:
                data -= 1
            if data >= 1:
                cand = MeshPlan(pod=pods, data=data, model=model)
                if best is None or cand.chips > best.chips:
                    best = cand
            model //= 2
        pods -= 1
    return best


class HeartbeatRegistry:
    """Host liveness by heartbeat timeout."""

    def __init__(self, timeout_s: float = 60.0, clock=time.monotonic):
        self.timeout_s = timeout_s
        self._clock = clock
        self._last: dict[str, float] = {}
        self._chips: dict[str, int] = {}

    def heartbeat(self, host: str, chips: int = 4) -> None:
        self._last[host] = self._clock()
        self._chips[host] = chips

    def live_hosts(self) -> list[str]:
        now = self._clock()
        return [h for h, t in self._last.items()
                if now - t <= self.timeout_s]

    def dead_hosts(self) -> list[str]:
        now = self._clock()
        return [h for h, t in self._last.items() if now - t > self.timeout_s]

    def live_chips(self) -> int:
        return sum(self._chips[h] for h in self.live_hosts())


@dataclasses.dataclass
class ElasticEvent:
    kind: str          # "shrink" | "grow" | "steady"
    old_plan: Optional[MeshPlan]
    new_plan: Optional[MeshPlan]


class ElasticController:
    """Decides when to re-mesh. Shrinks immediately on failure; grows only
    past hysteresis (re-meshing costs a checkpoint restore)."""

    def __init__(self, registry: HeartbeatRegistry, *, global_batch: int,
                 grow_hysteresis: float = 1.25):
        self.registry = registry
        self.global_batch = global_batch
        self.grow_hysteresis = grow_hysteresis
        self.plan: Optional[MeshPlan] = None

    def evaluate(self) -> ElasticEvent:
        chips = self.registry.live_chips()
        new = plan_mesh(chips, global_batch=self.global_batch)
        old = self.plan
        if old is None:
            self.plan = new
            return ElasticEvent("grow" if new else "steady", old, new)
        if new is None or new.chips < old.chips:
            self.plan = new
            return ElasticEvent("shrink", old, new)
        if new.chips >= old.chips * self.grow_hysteresis:
            self.plan = new
            return ElasticEvent("grow", old, new)
        return ElasticEvent("steady", old, old)
