"""Runtime: elasticity, failure handling, straggler mitigation."""
from repro.runtime.elastic import (ElasticController, HeartbeatRegistry,
                                   MeshPlan, plan_mesh)
from repro.runtime.straggler import HostMonitor, StepTimer, rebalance_edges

__all__ = ["ElasticController", "HeartbeatRegistry", "HostMonitor",
           "MeshPlan", "StepTimer", "plan_mesh", "rebalance_edges"]
