"""Straggler detection & mitigation (DESIGN §6).

``StepTimer`` — per-step EWMA + outlier detection on the training loop.
``HostMonitor`` — fleet view: per-host step-duration EWMAs, quarantine
policy for hosts persistently slower than the fleet median (at pod scale,
one slow host gates every synchronous collective).

For DiskJoin's executor, mitigation is cheap: edge tasks are independent,
so ``rebalance_edges`` moves queued edges from quarantined hosts to healthy
ones (no recompute, no checkpoint restore).
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict

import numpy as np


class StepTimer:
    def __init__(self, alpha: float = 0.1, outlier_factor: float = 2.5):
        self.alpha = alpha
        self.outlier_factor = outlier_factor
        self.ewma = None
        self.count = 0
        self.outliers = 0
        self._all: list[float] = []

    def record(self, seconds: float) -> bool:
        """Returns True if this step was an outlier (straggle event)."""
        self._all.append(seconds)
        self.count += 1
        if self.ewma is None:
            self.ewma = seconds
            return False
        is_outlier = seconds > self.outlier_factor * self.ewma
        if is_outlier:
            self.outliers += 1
        else:  # outliers don't poison the baseline
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * seconds
        return is_outlier

    @property
    def mean_ms(self) -> float:
        return 1000 * float(np.mean(self._all)) if self._all else 0.0

    def report(self) -> dict:
        if not self._all:
            return {}
        arr = np.asarray(self._all)
        return {
            "steps": self.count,
            "mean_ms": 1000 * float(arr.mean()),
            "p50_ms": 1000 * float(np.percentile(arr, 50)),
            "p99_ms": 1000 * float(np.percentile(arr, 99)),
            "outliers": self.outliers,
        }


@dataclasses.dataclass
class HostStats:
    ewma: float = 0.0
    steps: int = 0
    quarantined: bool = False


class HostMonitor:
    """Fleet-level straggler policy: quarantine hosts whose EWMA exceeds
    ``threshold ×`` the fleet median for ``patience`` consecutive checks."""

    def __init__(self, threshold: float = 1.5, patience: int = 3,
                 alpha: float = 0.2):
        self.threshold = threshold
        self.patience = patience
        self.alpha = alpha
        self.hosts: dict[str, HostStats] = defaultdict(HostStats)
        self._strikes: dict[str, int] = defaultdict(int)

    def record(self, host: str, seconds: float) -> None:
        st = self.hosts[host]
        st.ewma = seconds if st.steps == 0 else \
            (1 - self.alpha) * st.ewma + self.alpha * seconds
        st.steps += 1

    def evaluate(self) -> list[str]:
        """Run the policy; returns newly quarantined hosts."""
        active = {h: s for h, s in self.hosts.items() if not s.quarantined}
        if len(active) < 2:
            return []
        median = float(np.median([s.ewma for s in active.values()]))
        newly = []
        for h, s in active.items():
            if s.ewma > self.threshold * median:
                self._strikes[h] += 1
                if self._strikes[h] >= self.patience:
                    s.quarantined = True
                    newly.append(h)
            else:
                self._strikes[h] = 0
        return newly

    def healthy_hosts(self) -> list[str]:
        return [h for h, s in self.hosts.items() if not s.quarantined]


def rebalance_edges(assignment: dict[str, list], quarantined: list[str],
                    healthy: list[str]) -> dict[str, list]:
    """Move pending join-edge tasks off quarantined hosts, round-robin."""
    if not healthy:
        raise RuntimeError("no healthy hosts to rebalance onto")
    out = {h: list(v) for h, v in assignment.items() if h not in quarantined}
    moved = [e for h in quarantined for e in assignment.get(h, [])]
    for i, e in enumerate(moved):
        out.setdefault(healthy[i % len(healthy)], []).append(e)
    return out
