"""Shared benchmark scaffolding: datasets, timing, CSV emission."""
from __future__ import annotations

import hashlib
import json
import os
import platform
import sys
import tempfile
import time

import numpy as np

from repro.core import JoinConfig, recall, similarity_self_join
from repro.data import (brute_force_pairs, clustered_vectors,
                        epsilon_for_avg_neighbors)
from repro.store.vector_store import FlatVectorStore

# benchmark scale knob: the paper runs 100M–1.4B vectors on NVMe; this
# container validates the same algorithms at laptop scale (repro band 5/5).
SMALL = os.environ.get("REPRO_BENCH_SMALL", "0") == "1"

# one seed for every figure's synthetic data: a regression diff between
# two BENCH_*.json records is only meaningful when both ran identical
# work, and the record carries the seed so regress.py can check that
BENCH_SEED = int(os.environ.get("REPRO_BENCH_SEED", "1"))

# perf-trajectory collection (benchmarks/run.py --json-out): emit() mirrors
# every row here, keyed by figure module, and attach_stats() adds
# trace-derived quantities; run.py diffs COLLECTED around each module and
# writes BENCH_<figure>.json
COLLECTED: dict[str, list[dict]] = {}
TRACE_STATS: dict[str, dict] = {}
_CURRENT_FIGURE = "unknown"


def set_figure(name: str) -> None:
    """run.py points collection at the module it is about to run."""
    global _CURRENT_FIGURE
    _CURRENT_FIGURE = name


def attach_stats(figure: str | None = None, **stats) -> None:
    """Attach trace/metrics-derived scalars to the current figure's
    trajectory record (e.g. ``attach_stats(read_hidden_fraction=0.93)``)."""
    TRACE_STATS.setdefault(figure or _CURRENT_FIGURE, {}).update(stats)


def config_fingerprint() -> dict:
    """Environment fingerprint stamped into every BENCH_<fig>.json so a
    trajectory point is comparable only against points from like runs."""
    import jax
    env = {
        "small": SMALL,
        "python": platform.python_version(),
        "numpy": np.__version__,
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "platform": platform.platform(),
    }
    blob = json.dumps(env, sort_keys=True).encode()
    env["sha"] = hashlib.sha256(blob).hexdigest()[:12]
    return env


def scale(n: int) -> int:
    return max(1000, n // 10) if SMALL else n


def dataset(n: int, dim: int = 64, seed: int = 1, avg_neighbors: int = 20):
    x = clustered_vectors(n, dim, seed=seed)
    eps = epsilon_for_avg_neighbors(x, avg_neighbors, seed=seed)
    return x, eps


def make_store(x: np.ndarray, workdir: str | None = None):
    workdir = workdir or tempfile.mkdtemp(prefix="bench_")
    return FlatVectorStore.from_array(
        os.path.join(workdir, "data.bin"), x), workdir


def run_join(x: np.ndarray, eps: float, **cfg_kw):
    store, workdir = make_store(x)
    defaults = dict(epsilon=eps, recall_target=0.9,
                    memory_budget_bytes=max(1 << 20, x.nbytes // 10),
                    num_buckets=max(16, x.shape[0] // 100), pad_align=64)
    defaults.update(cfg_kw)
    cfg = JoinConfig(**defaults)
    t0 = time.perf_counter()
    res = similarity_self_join(store, cfg, workdir=workdir)
    elapsed = time.perf_counter() - t0
    return res, elapsed, store


def emit(name: str, rows: list[dict]) -> None:
    """name,us_per_call,derived CSV convention + full row dump."""
    for r in rows:
        derived = ";".join(f"{k}={v}" for k, v in r.items()
                           if k not in ("name", "us_per_call"))
        print(f"{r.get('name', name)},{r.get('us_per_call', '')},{derived}")
    COLLECTED.setdefault(_CURRENT_FIGURE, []).extend(
        {**r, "_emit": name} for r in rows)


def timed_us(fn, *args, repeats: int = 1, **kw) -> tuple[float, object]:
    t0 = time.perf_counter()
    out = None
    for _ in range(repeats):
        out = fn(*args, **kw)
    return (time.perf_counter() - t0) * 1e6 / repeats, out
