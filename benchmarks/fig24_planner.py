"""Fig. 24 (beyond-paper): cost-based adaptive planner — estimate-driven
pair capacity, batching, routing and admission vs. static knobs.

The planner (``repro.plan``) sizes the device engine's compaction buffer
from the cardinality sketch's Wilson upper bound instead of a static
default. The A/B here hand-mistunes the static default (``pair_cap=64``,
the kind of config drift the paper's static-knob baseline suffers at
scale): the static run overflows compaction and pays sticky re-dispatch;
the planned run, under the *same* mistuned default, passes an explicit
estimate-derived cap and never overflows — with byte-identical results,
because plans only size and place work, they never change semantics.

The serving section replays a deadline mix through the scheduler and
compares the planner's pre-read admission verdict
(``predicted service > deadline``) against the ground-truth outcome of
actually running each request — reporting the precision/recall of
estimate-based admission (``admission="estimate"`` would shed exactly the
predicted-doomed set at the door, before any SSD read).

CI gates (REPRO_BENCH_SMALL=1): planned-run ``device_compact_overflows``
== 0 while the mistuned static run overflows > 0, and planned/static
pairs+distances are byte-identical. Admission precision/recall are
reported (``attach_stats``) but not gated — warm-cache effects make
individual service times environment-dependent.
"""
from __future__ import annotations

import contextlib

import numpy as np

from benchmarks.common import (attach_stats, dataset, emit, make_store,
                               run_join, scale)

LATENCY_S = 2e-4     # light SSD latency: verify sizing is the frontier
TINY_PAIR_CAP = 64   # the hand-mistuned static default
SERVE_LATENCY_S = 0.02
TIGHT_DEADLINE_S = 0.01
LOOSE_DEADLINE_S = 30.0
REPS = 2             # first rep pays jit compilation; report the warm rep


@contextlib.contextmanager
def mistuned_device_default(cap: int = TINY_PAIR_CAP):
    """Force the device engine's *default* compaction capacity down to
    ``cap``. Explicit caps (``pair_cap`` kwarg set — what a JoinPlan
    passes) are untouched, so planner-on runs inside this context see the
    planned capacity while planner-off runs see the mistuned default."""
    from repro.compute import engine as eng
    orig = eng.DeviceVerifyEngine.__init__

    def patched(self, cache, **kw):
        if kw.get("pair_cap") is None:
            kw["pair_cap"] = cap
        orig(self, cache, **kw)

    eng.DeviceVerifyEngine.__init__ = patched
    try:
        yield
    finally:
        eng.DeviceVerifyEngine.__init__ = orig


def main() -> None:
    n = scale(8000)
    x, eps = dataset(n, dim=32, avg_neighbors=10)
    rows = []
    results = {}

    grid = [
        # the A/B under the mistuned default: static overflows, planned
        # carries its own estimate-derived cap
        ("static_tiny", True, dict(compute_mode="device")),
        ("planned", True, dict(compute_mode="device", plan_mode="on")),
        # planner with free choice of route per unit (this container's
        # unified memory makes the host path cheapest; the link-emulated
        # regime that flips it to device is covered by tests/fig23)
        ("planned_auto", False, dict(compute_mode="auto", plan_mode="on")),
    ]
    for name, mistune, cfg in grid:
        ctx = mistuned_device_default() if mistune else contextlib.nullcontext()
        with ctx:
            for _ in range(REPS):
                res, t, _ = run_join(x, eps, io_mode="prefetch",
                                     io_threads=4,
                                     num_buckets=max(16, n // 130),
                                     emulate_read_latency_s=LATENCY_S,
                                     **cfg)
        pipe = res.io_stats.get("pipeline", {})
        plan = res.plan
        rows.append({
            "name": f"fig24/{name}",
            "us_per_call": f"{t*1e6:.0f}",
            "total_s": f"{t:.3f}",
            "compute_s": f"{res.timings['compute']:.4f}",
            "pairs": res.pairs.shape[0],
            "overflows": pipe.get("device_compact_overflows", 0),
            "pair_cap": plan.pair_cap if plan is not None else TINY_PAIR_CAP
                        if mistune else "default",
            "compute": plan.compute_mode if plan is not None
                       else cfg["compute_mode"],
            "plans": pipe.get("plans", 0),
        })
        results[name] = res

    # -- serving: admission verdict vs ground-truth outcome ----------------
    from repro.core import DiskJoinIndex, JoinConfig
    from repro.serve import DeadlineExceeded, QueryScheduler

    qx, qeps = dataset(scale(4000), dim=32, avg_neighbors=10)
    store, wd = make_store(qx)
    # pool far smaller than the index: most probe reads are cold, so the
    # emulated SSD latency dominates service time and tight deadlines are
    # genuinely infeasible — the regime admission control exists for
    qcfg = JoinConfig(epsilon=qeps, pad_align=64, num_buckets=32,
                      memory_budget_bytes=1 << 17)
    n_queries = 32
    deadlines = [TIGHT_DEADLINE_S if i % 2 else LOOSE_DEADLINE_S
                 for i in range(n_queries)]
    tp = fp = fn = tn = 0
    with DiskJoinIndex.build(store, qcfg, wd) as idx:
        idx.query_batch(qx[:1])          # pay jit before timing anything
        with QueryScheduler(idx, max_wait_s=0.0,
                            emulate_read_latency_s=SERVE_LATENCY_S) as s:
            for i in range(n_queries):
                q = qx[i]
                pred = s._predict_service_s(q, dict(s._overrides))
                doomed = (pred is not None
                          and s.max_wait_s + pred > deadlines[i])
                fut = s.submit(q, deadline_s=deadlines[i])
                try:
                    fut.result(timeout=60)
                    dropped = False
                except DeadlineExceeded:
                    dropped = True
                tp += doomed and dropped
                fp += doomed and not dropped
                fn += dropped and not doomed
                tn += not doomed and not dropped
    precision = tp / max(1, tp + fp)
    recall = tp / max(1, tp + fn)
    rows.append({
        "name": "fig24/admission",
        "us_per_call": "",
        "queries": n_queries,
        "predicted_doomed": tp + fp,
        "dropped": tp + fn,
        "precision": f"{precision:.2f}",
        "recall": f"{recall:.2f}",
    })

    emit("fig24", rows)
    attach_stats(admission_precision=precision, admission_recall=recall,
                 admission_predicted_doomed=tp + fp,
                 admission_dropped=tp + fn)

    # -- acceptance gates ---------------------------------------------------
    r_static, r_plan = results["static_tiny"], results["planned"]
    p_static = r_static.io_stats["pipeline"]
    p_plan = r_plan.io_stats["pipeline"]
    assert p_static["device_compact_overflows"] > 0, (
        "mistuned static baseline did not overflow — A/B is vacuous")
    assert p_plan["device_compact_overflows"] == 0, (
        f"planned pair_cap {r_plan.plan.pair_cap} still overflowed "
        f"{p_plan['device_compact_overflows']}x")
    assert np.array_equal(r_static.pairs, r_plan.pairs), \
        "planner changed the result pair set"
    assert np.array_equal(r_static.distances, r_plan.distances), \
        "planner changed result distances"
    assert np.array_equal(r_static.pairs, results["planned_auto"].pairs), \
        "auto-routed plan changed the result pair set"
    print(f"# fig24 summary: parity=OK planned_pair_cap="
          f"{r_plan.plan.pair_cap} static_overflows="
          f"{p_static['device_compact_overflows']} planned_overflows=0 "
          f"admission precision={precision:.2f} recall={recall:.2f} "
          f"({tp + fp} predicted / {tp + fn} dropped of {n_queries})")


if __name__ == "__main__":
    main()
