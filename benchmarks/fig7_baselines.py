"""Fig. 7: DiskJoin vs ClusterJoin vs RSHJ — time + distance computations
across growing dataset sizes. Paper claim: DiskJoin DCs grow ~linearly,
ClusterJoin near-quadratically; RSHJ OOMs at scale."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import dataset, emit, run_join, scale
from repro.baselines import cluster_join, rshj_join
from repro.core import recall
from repro.data import brute_force_pairs


def main() -> None:
    rows = []
    for n in (scale(4000), scale(10000), scale(25000)):
        x, eps = dataset(n, dim=32, avg_neighbors=10)
        truth = brute_force_pairs(x, eps) if n <= 30000 else None

        res, t_dj, _ = run_join(x, eps, recall_target=0.995)
        rows.append({
            "name": f"fig7/diskjoin/n={n}",
            "us_per_call": f"{t_dj*1e6:.0f}",
            "seconds": f"{t_dj:.2f}",
            "distance_computations": res.num_distance_computations,
            "recall": (f"{recall(res.pairs, truth):.4f}"
                       if truth is not None else "n/a"),
        })

        t0 = time.perf_counter()
        pairs, dc = cluster_join(x, eps)
        t_cj = time.perf_counter() - t0
        rows.append({
            "name": f"fig7/clusterjoin/n={n}",
            "us_per_call": f"{t_cj*1e6:.0f}",
            "seconds": f"{t_cj:.2f}",
            "distance_computations": dc,
            "recall": "1.0000",  # exact
        })

        try:
            t0 = time.perf_counter()
            pairs, dc = rshj_join(x, eps, tables=16, k=3,
                                  max_candidates=4_000_000)
            t_r = time.perf_counter() - t0
            rows.append({
                "name": f"fig7/rshj/n={n}",
                "us_per_call": f"{t_r*1e6:.0f}",
                "seconds": f"{t_r:.2f}",
                "distance_computations": dc,
                "recall": (f"{recall(pairs, truth):.4f}"
                           if truth is not None else "n/a"),
            })
        except MemoryError as e:
            rows.append({"name": f"fig7/rshj/n={n}", "us_per_call": "",
                         "status": "OOM (paper Fig.7: fails >=1M)"})
    emit("fig7", rows)


if __name__ == "__main__":
    main()
