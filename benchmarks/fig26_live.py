"""Live-observability acceptance (fig26; CI runs this figure).

One small index served under a three-phase emulated-SSD regime —
normal → degraded (read latency ×20) → recovered — with
``attach_live()`` watching. Four gates:

  1. **calibrator convergence** — the live read constant
     (``LiveCalibrator.read_s_per_bucket``, rolling per-window median)
     re-tracks the new ground-truth latency after the mid-run shift:
     its relative error vs the degraded latency *shrinks* across the
     degraded phase and lands within 50%.
  2. **burn-rate alert timing** — the latency SLO (threshold ≈ 4× the
     normal-phase p95) fires during the degraded phase and ONLY then,
     and resolves during recovery.
  3. **planner byte-neutrality** — ``query_batch`` with
     ``plan_mode="on"`` (live constants feeding the cost model through
     ``_planner_for``) returns results byte-identical to
     ``plan_mode="off"``.
  4. **overhead** — the fully-armed live stack (tracing + rollups +
     SLO monitor + calibrator) costs < 2% wall vs the same workload
     untraced (interleaved best-of-3).
"""
from __future__ import annotations

import os
import tempfile
import time

import numpy as np

from benchmarks.common import BENCH_SEED, attach_stats, dataset, emit, scale
from repro.core import DiskJoinIndex, JoinConfig
from repro.obs import get_tracer
from repro.obs.live import Slo

LAT_NORMAL_S = 2e-4     # emulated per-bucket read latency, healthy SSD
LAT_DEGRADED_S = 4e-3   # mid-run degradation (×20): throttled / failing
WINDOW_S = 0.12


def _serve_round(index, x, rng, eps, lat, queries=8):
    """One cold serving round: drop the warm cache so every query pays
    real (emulated) bucket reads, then answer a few random lookups.
    ``lat`` rides as a query-time override because ``_resolve`` re-applies
    the config's ``emulate_read_latency_s`` to the store on every call —
    poking ``store.read_latency_s`` directly would be overwritten."""
    index.drop_warm_cache()
    picks = rng.choice(x.shape[0], queries)
    for qi in picks:
        index.query(x[qi], epsilon=eps, emulate_read_latency_s=lat)


def _phase_round(index, x, rng, eps, lat, obs):
    """One serving round spread onto its own rollup window: the rollup's
    clock is real time, so consecutive rounds must be window-spaced for
    the calibrator/SLO monitor to see a *series* of windows."""
    _serve_round(index, x, rng, eps, lat)
    time.sleep(WINDOW_S)
    obs.poll()


def main() -> None:
    n = scale(6000)
    rng = np.random.default_rng(BENCH_SEED)
    x, eps = dataset(n, dim=24, seed=BENCH_SEED, avg_neighbors=8)
    workdir = tempfile.mkdtemp(prefix="fig26_live_")
    from repro.store.vector_store import FlatVectorStore
    store = FlatVectorStore.from_array(os.path.join(workdir, "x.bin"), x)
    cfg = JoinConfig(epsilon=eps, recall_target=0.9, pad_align=64,
                     num_buckets=max(24, n // 150),
                     memory_budget_bytes=max(1 << 20, x.nbytes // 10))
    index = DiskJoinIndex.build(store, cfg, os.path.join(workdir, "idx"))
    rows = []

    # -- gate 4 first: live-stack overhead bound ------------------------------
    # Same accounting idiom as the obs acceptance test: microbench the
    # per-event cost of the armed recording path (ring append + rollup
    # sink fold), multiply by the events the real workload recorded, and
    # bound against its wall. Wall-diff A/B timing is hopeless here —
    # the emulated-SSD sleeps jitter ±10% run to run, far above the
    # sub-1% signal being gated.
    def workload():
        _serve_round(index, x, np.random.default_rng(7), eps,
                     LAT_NORMAL_S, queries=96)

    workload()  # warm code paths/jit before timing
    obs = index.attach_live(window_s=WINDOW_S)
    tr = index.tracer
    reps = 2000
    t0 = time.perf_counter()
    for _ in range(reps):
        tr.complete("io.read", t0, 1e-4, buckets=1)
    per_event_s = (time.perf_counter() - t0) / reps
    e0 = obs.timeseries.events_folded
    t0 = time.perf_counter()
    workload()
    live_wall = time.perf_counter() - t0
    events = obs.timeseries.events_folded - e0
    index.detach_live()
    overhead = per_event_s * events / live_wall
    assert overhead < 0.02, \
        (f"live observability overhead {overhead:.1%} ≥ 2% "
         f"({events} events × {per_event_s * 1e6:.1f}µs on a "
         f"{live_wall * 1e3:.0f}ms workload)")

    # -- attach for the three-phase regime demo ------------------------------
    alerts_log = []          # (phase, Alert) in arrival order
    phase = ["normal"]
    slos = (
        # threshold set after the normal phase's first windows land; the
        # default here (4× the emulated floor × typical probe fan-out) is
        # deliberately generous so "normal" traffic never burns
        Slo.latency("query_p95_latency", "query.execute",
                    threshold_s=16 * LAT_NORMAL_S, objective=0.9,
                    fast_windows=2, slow_windows=4, burn_threshold=2.0),
    )
    obs = index.attach_live(window_s=WINDOW_S, slos=slos,
                            calibrate_windows=4, calibrate_min_samples=4,
                            on_alert=lambda a: alerts_log.append(
                                (phase[0], a)))

    def read_err(truth: float) -> float | None:
        c = obs.live_constants().get("read_s_per_bucket")
        if not c:
            return None
        return abs(c["value"] - truth) / truth

    # phase 1: normal — calibrator locks on, SLO quiet
    for _ in range(8):
        _phase_round(index, x, rng, eps, LAT_NORMAL_S, obs)
    err_normal = read_err(LAT_NORMAL_S)
    assert err_normal is not None, "calibrator produced no read constant"
    assert not any(a.state == "firing" for _, a in alerts_log), \
        "SLO fired during the healthy phase"

    # phase 2: degraded — ×20 read latency, mid-run
    phase[0] = "degraded"
    errs = []
    for _ in range(10):
        _phase_round(index, x, rng, eps, LAT_DEGRADED_S, obs)
        e = read_err(LAT_DEGRADED_S)
        if e is not None:
            errs.append(e)
    err_first, err_last = errs[0], errs[-1]
    # monotone shrink only matters while still far off — once the first
    # reading is already converged, window-to-window noise may tick the
    # error up a point or two
    assert err_last <= err_first or err_last < 0.2, \
        (f"live read constant diverged across the degraded phase: "
         f"error {err_first:.2f} → {err_last:.2f}")
    assert err_last < 0.5, \
        f"live read constant never converged: {err_last:.1%} off"
    fired_phases = {ph for ph, a in alerts_log if a.state == "firing"}
    assert fired_phases == {"degraded"}, \
        f"alert fired in phases {sorted(fired_phases)}, want degraded only"

    # phase 3: recovered — latency restored, alert must resolve
    phase[0] = "recovered"
    for _ in range(8):
        _phase_round(index, x, rng, eps, LAT_NORMAL_S, obs)
    resolved_phases = {ph for ph, a in alerts_log if a.state == "resolved"}
    assert "recovered" in resolved_phases, \
        "alert never resolved after the latency recovered"
    err_recovered = read_err(LAT_NORMAL_S)

    # -- gate 3: planner byte-neutrality with live constants flowing ---------
    assert obs.live_constants(), "no live constants feeding the planner"
    Qp = x[rng.choice(n, 24)]
    base_res = index.query_batch(Qp, plan_mode="off")
    plan_res = index.query_batch(Qp, plan_mode="on")
    for qi, ((bi, bd), (pi, pd)) in enumerate(zip(base_res, plan_res)):
        bo, po = np.argsort(bi), np.argsort(pi)
        assert np.array_equal(bi[bo], pi[po]) and \
            np.array_equal(bd[bo], pd[po]), \
            f"planner changed query {qi}'s result bytes"

    fired = sum(1 for _, a in alerts_log if a.state == "firing")
    resolved = sum(1 for _, a in alerts_log if a.state == "resolved")
    snap = index.metrics_snapshot()
    rows.append({
        "name": "fig26_live/regime_shift",
        "us_per_call": "",
        "overhead_frac": f"{overhead:.4f}",
        "read_err_normal": f"{err_normal:.3f}",
        "read_err_degraded_first": f"{err_first:.3f}",
        "read_err_degraded_last": f"{err_last:.3f}",
        "read_err_recovered":
            "" if err_recovered is None else f"{err_recovered:.3f}",
        "alerts_fired": fired,
        "alerts_resolved": resolved,
        "rollup_events": snap["live"]["events"],
        "tracer_dropped": snap["tracer"]["dropped"],
        "planner_byte_parity": 1,
    })
    attach_stats(live_overhead_frac=overhead,
                 read_err_degraded_last=err_last,
                 alerts_fired=fired, alerts_resolved=resolved,
                 planner_byte_parity=1.0)
    emit("fig26_live", rows)
    print(f"# fig26_live summary: overhead={overhead:.2%}, degraded read "
          f"err {err_first:.2f}→{err_last:.2f}, alerts fired={fired} "
          f"resolved={resolved}, planner byte-parity ok")
    index.detach_live()
    assert not get_tracer().enabled, "detach_live left tracing enabled"
    index.close()


if __name__ == "__main__":
    main()
