"""Perf-regression gate over ``run.py --json-out`` records.

Compares a directory of fresh ``BENCH_<figure>.json`` records against
the committed baselines in ``benchmarks/baselines/`` and reports, per
common figure:

  * **status** — a figure that passed at baseline must still pass.
  * **wall_s** — multiplicative noise band (default ×3: smoke-scale CI
    wall times jitter hugely; the gate is for order-of-magnitude
    blowups, the trajectory archive is for trend analysis).
  * **trace_stats** — every numeric stat present in both records,
    direction-classified by name (``HIGHER_BETTER``/``LOWER_BETTER``
    substrings; unknown names are report-only). Fraction-like values
    (both within [0, 1.5]) use an absolute band (default 0.15), others
    a multiplicative band.

``--check`` exits 1 when any out-of-band regression survives; the full
diff (regressions, improvements, in-band drift, coverage gaps) is
written as JSON for CI artifact upload either way.

Usage::

    python benchmarks/run.py --json-out bench_out          # fresh records
    python benchmarks/regress.py bench_out --check \\
        --diff-out bench_out/regress_diff.json

The comparison functions are pure (no I/O) so tests drive them with
synthetic records.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

BASELINE_DIR = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                            "baselines")

# direction classification by name substring (first match wins,
# HIGHER_BETTER checked first). Unknown stats are reported, not gated.
HIGHER_BETTER = ("goodput", "overlap", "hidden", "precision", "recall",
                 "hit", "saved", "parity", "coverage", "resumed",
                 "restarts")
LOWER_BETTER = ("overhead", "drop", "error", "err", "wall", "elapsed",
                "latency", "dropped", "failover", "mismatch")

WALL_BAND = 3.0          # fresh wall may be up to 3× baseline
FRAC_BAND = 0.15         # absolute band for fraction-like stats
MULT_BAND = 2.0          # multiplicative band for other stats


def classify(name: str) -> str:
    """'higher' | 'lower' | 'unknown' — which direction is good."""
    low = name.lower()
    for frag in HIGHER_BETTER:
        if frag in low:
            return "higher"
    for frag in LOWER_BETTER:
        if frag in low:
            return "lower"
    return "unknown"


def _is_fraction_like(a: float, b: float) -> bool:
    return 0.0 <= a <= 1.5 and 0.0 <= b <= 1.5


def compare_stat(name: str, base: float, fresh: float, *,
                 frac_band: float = FRAC_BAND,
                 mult_band: float = MULT_BAND) -> dict:
    """One stat's verdict: ``{name, base, fresh, direction, verdict}``
    with verdict ∈ regression | improvement | ok | info."""
    direction = classify(name)
    out = {"name": name, "base": base, "fresh": fresh,
           "direction": direction}
    if direction == "unknown":
        out["verdict"] = "info"
        return out
    # delta in the "bad" direction, normalized to the band in use
    if _is_fraction_like(base, fresh):
        delta = fresh - base
        bad = delta < -frac_band if direction == "higher" \
            else delta > frac_band
        good = delta > frac_band if direction == "higher" \
            else delta < -frac_band
    else:
        hi, lo = base * mult_band, base / mult_band
        if direction == "higher":
            bad, good = fresh < lo, fresh > hi
        else:
            bad, good = fresh > hi, fresh < lo
    out["verdict"] = ("regression" if bad
                      else "improvement" if good else "ok")
    return out


def compare_records(base: dict, fresh: dict, *,
                    wall_band: float = WALL_BAND) -> dict:
    """Compare one figure's baseline vs fresh record → diff dict with
    ``regressions`` (the gated list), ``improvements``, ``ok``,
    ``info``."""
    fig = base.get("figure") or fresh.get("figure")
    diff = {"figure": fig, "regressions": [], "improvements": [],
            "ok": [], "info": []}

    def put(entry: dict) -> None:
        key = {"regression": "regressions", "improvement": "improvements",
               "ok": "ok", "info": "info"}[entry["verdict"]]
        diff[key].append(entry)

    if base.get("status") == "ok" and fresh.get("status") != "ok":
        put({"name": "status", "base": base.get("status"),
             "fresh": fresh.get("status"), "direction": "lower",
             "verdict": "regression"})
    bw, fw = base.get("wall_s"), fresh.get("wall_s")
    if isinstance(bw, (int, float)) and isinstance(fw, (int, float)) \
            and bw > 0:
        put({"name": "wall_s", "base": bw, "fresh": fw,
             "direction": "lower",
             "verdict": "regression" if fw > bw * wall_band
             else "improvement" if fw < bw / wall_band else "ok"})
    bs = base.get("trace_stats") or {}
    fs = fresh.get("trace_stats") or {}
    for name in sorted(set(bs) & set(fs)):
        b, f = bs[name], fs[name]
        if isinstance(b, (int, float)) and isinstance(f, (int, float)) \
                and not isinstance(b, bool) and not isinstance(f, bool):
            put(compare_stat(name, float(b), float(f)))
    for name in sorted(set(bs) - set(fs)):
        diff["info"].append({"name": name, "base": bs[name],
                             "fresh": None, "direction": "unknown",
                             "verdict": "info"})
    return diff


def load_records(dirpath: str) -> dict:
    """``{figure: record}`` for every BENCH_*.json in ``dirpath``."""
    out = {}
    if not os.path.isdir(dirpath):
        return out
    for fn in sorted(os.listdir(dirpath)):
        if not (fn.startswith("BENCH_") and fn.endswith(".json")):
            continue
        with open(os.path.join(dirpath, fn)) as f:
            rec = json.load(f)
        out[rec.get("figure", fn[len("BENCH_"):-len(".json")])] = rec
    return out


def compare_dirs(fresh_dir: str, baseline_dir: str = BASELINE_DIR,
                 *, wall_band: float = WALL_BAND) -> dict:
    """Full run diff: per-figure comparisons over the figure
    intersection, plus coverage notes for one-sided figures."""
    base = load_records(baseline_dir)
    fresh = load_records(fresh_dir)
    figures = [compare_records(base[k], fresh[k], wall_band=wall_band)
               for k in sorted(set(base) & set(fresh))]
    return {
        "baseline_dir": baseline_dir,
        "fresh_dir": fresh_dir,
        "compared": sorted(set(base) & set(fresh)),
        "baseline_only": sorted(set(base) - set(fresh)),
        "fresh_only": sorted(set(fresh) - set(base)),
        "figures": figures,
        "num_regressions": sum(len(d["regressions"]) for d in figures),
    }


def render(diff: dict) -> str:
    lines = [f"perf-regress: {len(diff['compared'])} figure(s) compared "
             f"against {diff['baseline_dir']}"]
    for figd in diff["figures"]:
        regs, imps = figd["regressions"], figd["improvements"]
        if not regs and not imps:
            lines.append(f"  {figd['figure']}: ok "
                         f"({len(figd['ok'])} stats in band)")
            continue
        lines.append(f"  {figd['figure']}:")
        for r in regs:
            lines.append(f"    REGRESSION {r['name']}: "
                         f"{r['base']} -> {r['fresh']} "
                         f"(want {r['direction']})")
        for i in imps:
            lines.append(f"    improvement {i['name']}: "
                         f"{i['base']} -> {i['fresh']}")
    if diff["baseline_only"]:
        lines.append(f"  not re-run (baseline only): "
                     f"{', '.join(diff['baseline_only'])}")
    if diff["fresh_only"]:
        lines.append(f"  new figures (no baseline yet): "
                     f"{', '.join(diff['fresh_only'])}")
    lines.append(f"  total regressions: {diff['num_regressions']}")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("fresh_dir",
                    help="directory of fresh BENCH_*.json records")
    ap.add_argument("--baselines", default=BASELINE_DIR,
                    help="baseline record directory "
                         "(default: benchmarks/baselines)")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 when out-of-band regressions exist")
    ap.add_argument("--wall-band", type=float, default=WALL_BAND,
                    help="allowed fresh/baseline wall-time ratio")
    ap.add_argument("--diff-out", default=None,
                    help="write the full diff JSON here (CI artifact)")
    args = ap.parse_args(argv)

    diff = compare_dirs(args.fresh_dir, args.baselines,
                        wall_band=args.wall_band)
    print(render(diff))
    if args.diff_out:
        os.makedirs(os.path.dirname(os.path.abspath(args.diff_out)),
                    exist_ok=True)
        with open(args.diff_out, "w") as f:
            json.dump(diff, f, indent=2)
        print(f"# wrote {args.diff_out}")
    if args.check and diff["num_regressions"] > 0:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
