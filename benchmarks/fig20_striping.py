"""Fig. 20 (beyond-paper): multi-SSD striping sweep — devices × lookahead ×
coalescing, under emulated SSD access latency.

The schedule knows every future read, so one NVMe queue should never be
the ceiling: striping the bucketed store over D backing files and giving
the prefetcher one submission queue per device should scale effective
read bandwidth ≈ linearly in D until compute stops hiding behind I/O.
Batched submission + coalescing additionally merge disk-contiguous
schedule-adjacent misses (the writer lays extents out in schedule order)
into single sequential reads — fewer device round trips for the same
bytes.

Gates printed in the summary line:
  scaling — effective read bandwidth (useful bytes / execute wall) at 4
            stripes ≥ 2.5× the 1-stripe prefetch baseline.
  parity  — sync/prefetch × striped/unstriped all produce the identical
            pair set.
"""
from __future__ import annotations

from benchmarks.common import dataset, emit, run_join, scale

LATENCY_S = 2e-3  # ≥ 0.5 ms per device access — the I/O-bound regime


def _pair_keys(pairs):
    return set(map(tuple, pairs.tolist()))


def main() -> None:
    n = scale(8000)
    x, eps = dataset(n, dim=32, avg_neighbors=10)
    rows = []
    bw = {}

    run_join(x[:1000], eps, io_mode="sync")  # warm the verify-kernel jit

    res_sync, t_sync, _ = run_join(x, eps, io_mode="sync",
                                   emulate_read_latency_s=LATENCY_S)
    truth = _pair_keys(res_sync.pairs)
    parity_ok = True
    rows.append({
        "name": "fig20/sync_d1", "us_per_call": f"{t_sync*1e6:.0f}",
        "exec_s": f"{res_sync.timings['execute']:.3f}",
        "bw_MBps": f"{res_sync.io_stats['bytes_read_useful'] / max(res_sync.timings['execute'], 1e-9) / 1e6:.1f}",
        "loads": res_sync.bucket_loads,
    })

    for devices in (1, 2, 4):
        for lookahead in (4, 16):
            for co in (False, True):
                res, t, _ = run_join(
                    x, eps, io_mode="prefetch", io_devices=devices,
                    io_threads=1, io_lookahead=lookahead,
                    io_batch_reads=True, io_coalesce=co,
                    emulate_read_latency_s=LATENCY_S)
                parity_ok &= _pair_keys(res.pairs) == truth
                p = res.io_stats["pipeline"]
                exec_s = res.timings["execute"]
                mbps = (res.io_stats["bytes_read_useful"]
                        / max(exec_s, 1e-9) / 1e6)
                name = (f"fig20/prefetch_d{devices}_la{lookahead}"
                        f"{'_co' if co else ''}")
                bw[(devices, lookahead, co)] = mbps
                rows.append({
                    "name": name, "us_per_call": f"{t*1e6:.0f}",
                    "exec_s": f"{exec_s:.3f}",
                    "bw_MBps": f"{mbps:.1f}",
                    "loads": res.bucket_loads,
                    "io_wait_s": f"{res.timings['io_wait']:.4f}",
                    "dev_depth_max": "/".join(map(str, p["device_depth_max"])),
                    "dev_loads": "/".join(map(str, p["device_loads"])),
                    "batched_subs": p["batched_submissions"],
                    "coalesced_reads": p["coalesced_reads"],
                    "coalesced_buckets": p["coalesced_buckets"],
                })

    emit("fig20", rows)
    # acceptance gates: near-linear read-bandwidth scaling + result parity
    ratio = bw[(4, 16, False)] / max(bw[(1, 16, False)], 1e-9)
    ratio_co = bw[(4, 16, True)] / max(bw[(1, 16, True)], 1e-9)
    print(f"# fig20 summary: bw_d1={bw[(1, 16, False)]:.1f}MB/s "
          f"bw_d4={bw[(4, 16, False)]:.1f}MB/s ratio={ratio:.2f}x "
          f"ratio_coalesced={ratio_co:.2f}x "
          f"scaling={'OK' if ratio >= 2.5 else 'LOW'} "
          f"parity={'OK' if parity_ok else 'MISMATCH'}")


if __name__ == "__main__":
    main()
