"""Fig. 25 (beyond-paper): crash-safe joins and serving — checkpoint
overhead, kill/resume goodput, warm restarts and transient-read retry.

A billion-vector join at emulated SSD latency runs for hours; the paper's
engine loses everything on a mid-run kill. ``repro.ft`` adds an async
join checkpointer (superstep cursor + emitted-pair spill, committed
atomically off the verify path), so a killed run resumes at the last
committed superstep and still produces byte-identical pairs+distances.

Four sections, all at emulated SSD latency:

  * **overhead** — uninterrupted join with vs. without the checkpointer;
    the async writer must cost < 5% wall time.
  * **goodput** — kill the join ~60% in (``FaultInjector``), resume from
    the checkpoint directory. Goodput = (uninterrupted checkpointed
    wall) / (attempt₁ + restore + attempt₂); one kill must keep it
    ≥ 0.8. Resume output is gated byte-identical to the uninterrupted
    run.
  * **warm restart** — serving session closes (persisting its warm-set
    residency snapshot), reopens with ``warm_start=True``; the first
    post-restart query wave must hit warm slabs (``query_warm_hits``)
    instead of paying cold reads.
  * **retry** — a ``FlakyStore`` injects transient read errors under a
    query wave; capped-backoff retries absorb them (``io_retries``
    counters) with results identical to the clean run.

CI gates (REPRO_BENCH_SMALL=1): resume byte-parity, ckpt overhead < 5%,
goodput ≥ 0.8, first-wave warm hits > 0, retry-run parity.
"""
from __future__ import annotations

import os
import shutil
import tempfile
import time

import numpy as np

from benchmarks.common import attach_stats, dataset, emit, scale
from repro.core import (DiskJoinIndex, JoinConfig, bucketize,
                        build_bucket_graph)
from repro.core.distributed import DistributedJoin
from repro.ft import FaultInjector, FlakyStore, InjectedKill, JoinCheckpointer
from repro.store.vector_store import FlatVectorStore

from benchmarks.common import SMALL

# emulated SSD read latency: I/O dominates, the regime where async
# checkpointing must hide. The small (CI smoke) run uses a slower
# emulated drive so wall time stays large enough that the <5% overhead
# gate measures the checkpointer, not timer noise on a ~30 ms run.
LATENCY_S = 8e-3 if SMALL else 1e-3
KILL_FRACTION = 0.6  # kill the second attempt ~60% through
OVERHEAD_GATE = 0.05
GOODPUT_GATE = 0.8
OVERHEAD_REPS = 5    # interleaved best-of-N: the runs are sub-second,
                     # so the <5% gate needs drift-cancelling timing


def _timed_best(fn, reps: int = 2):
    best, out = float("inf"), None
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn()
        best = min(best, time.perf_counter() - t0)
    return best, out


def main() -> None:
    n = scale(6000)
    x, eps = dataset(n, dim=32, avg_neighbors=10)
    work = tempfile.mkdtemp(prefix="fig25_")
    rows = []

    # small budget => many supersteps => many checkpoint boundaries; the
    # kill must land mid-run, not after the only step
    cfg = JoinConfig(epsilon=eps, recall_target=0.9, pad_align=64,
                     num_buckets=max(16, n // 125),
                     memory_budget_bytes=max(96 << 10, x.nbytes // 12),
                     emulate_read_latency_s=LATENCY_S)
    flat = FlatVectorStore.from_array(os.path.join(work, "x.bin"), x)
    bstore, meta, _ = bucketize(flat, os.path.join(work, "bk"), cfg)
    graph = build_bucket_graph(meta, cfg)
    dj = DistributedJoin(bstore, meta, cfg)

    # -- overhead: checkpointer on vs. off, uninterrupted ------------------
    _, (base_pairs, base_info) = _timed_best(lambda: dj.run(graph), reps=1)
    # commit interval tuned to the run length (the standard
    # checkpoint-frequency/overhead trade): ~16 commits per run keeps
    # the async writer's GIL share negligible next to the verify loop,
    # while a kill still loses at most `every - 1` supersteps
    every = max(1, base_info["supersteps"] // 16)

    # interleave the two variants so background drift (page cache,
    # thermal, sibling load) hits both equally; the gate uses the best
    # adjacent-pair ratio — each ratio compares two back-to-back runs,
    # cancelling slow drift that best-of-N absolute times cannot. Each
    # rep gets a fresh pre-made checkpoint dir so directory cleanup
    # never lands inside the timed region.
    t_plain = t_ckpt = best_ratio = float("inf")
    ck_pairs, ck_info = None, None
    for rep in range(OVERHEAD_REPS):
        ckdir = os.path.join(work, f"ck_overhead_{rep}")
        tp, _out = _timed_best(lambda: dj.run(graph), reps=1)
        t_plain = min(t_plain, tp)
        tc, (ck_pairs, ck_info) = _timed_best(
            lambda: dj.run(graph,
                           checkpointer=JoinCheckpointer(ckdir,
                                                         every=every)),
            reps=1)
        t_ckpt = min(t_ckpt, tc)
        best_ratio = min(best_ratio, tc / tp)
    assert np.array_equal(ck_pairs, base_pairs), \
        "checkpointed run diverged from plain run"
    overhead = max(0.0, best_ratio - 1.0)
    rows.append({
        "name": "fig25/overhead",
        "us_per_call": f"{t_ckpt*1e6:.0f}",
        "plain_s": f"{t_plain:.3f}", "ckpt_s": f"{t_ckpt:.3f}",
        "overhead_pct": f"{overhead*100:.2f}",
        "supersteps": base_info["supersteps"], "every": every,
        "saves": ck_info["ckpt"]["saves"],
        "deferred": ck_info["ckpt"]["deferred"],
    })

    # -- goodput under one mid-run kill ------------------------------------
    kill_at = max(1, int(base_info["supersteps"] * KILL_FRACTION))

    def _kill_and_resume(rep: int):
        ckdir = os.path.join(work, f"ck_kill_{rep}")
        ck = JoinCheckpointer(ckdir, every=every)
        t0 = time.perf_counter()
        try:
            dj.run(graph, checkpointer=ck,
                   fault=FaultInjector(kill_at_superstep=kill_at))
            raise AssertionError("fault injector did not fire")
        except InjectedKill:
            a1 = time.perf_counter() - t0
        ck.finish()  # a real crash skips this; restore reaps torn tmp

        t0 = time.perf_counter()
        pairs, info = dj.run(
            graph, checkpointer=JoinCheckpointer(ckdir, every=every),
            resume_from=ckdir)
        a2 = time.perf_counter() - t0
        assert np.array_equal(pairs, base_pairs), \
            "resumed pairs diverged from uninterrupted run"
        assert np.array_equal(info["dists"], base_info["dists"]), \
            "resumed distances diverged from uninterrupted run"
        assert info["watermark_rows"] == base_info["watermark_rows"], \
            "raw emission stream duplicated/lost rows across the kill"
        return a1, a2, info

    # parity is asserted on every rep; the goodput *gate* takes the
    # best rep (single killed runs can't be best-of'd any other way)
    t_attempt1, t_attempt2, info = min(
        (_kill_and_resume(rep) for rep in range(2)),
        key=lambda r: r[0] + r[1])
    goodput = t_ckpt / (t_attempt1 + t_attempt2)
    rows.append({
        "name": "fig25/goodput",
        "us_per_call": f"{(t_attempt1 + t_attempt2)*1e6:.0f}",
        "killed_at": kill_at, "resumed_at": info["resumed_at"],
        "attempt1_s": f"{t_attempt1:.3f}",
        "attempt2_s": f"{t_attempt2:.3f}",
        "restore_s": f"{info['restore_s']:.4f}",
        "goodput": f"{goodput:.3f}",
    })

    # -- serving warm restart ----------------------------------------------
    idx_dir = os.path.join(work, "idx")
    icfg = JoinConfig(epsilon=eps, recall_target=0.9, pad_align=64,
                      num_buckets=max(16, n // 125),
                      memory_budget_bytes=max(1 << 20, x.nbytes // 10),
                      emulate_read_latency_s=LATENCY_S)
    idx = DiskJoinIndex.build(flat, icfg, idx_dir)
    q = x[: min(24, n)]
    t_cold, _ = _timed_best(lambda: idx.query_batch(q), reps=1)
    idx.close()  # persists the residency snapshot

    idx2 = DiskJoinIndex.open(idx_dir, warm_start=True)
    prefaults = idx2.pipeline_snapshot().get("warm_prefaults", 0)
    t_warm, out_warm = _timed_best(lambda: idx2.query_batch(q), reps=1)
    warm_hits = idx2.pipeline_snapshot().get("query_warm_hits", 0)
    assert prefaults > 0, "warm_start pre-faulted nothing"
    assert warm_hits > 0, "first post-restart wave paid only cold reads"
    rows.append({
        "name": "fig25/warm_restart",
        "us_per_call": f"{t_warm*1e6:.0f}",
        "cold_first_wave_s": f"{t_cold:.4f}",
        "warm_first_wave_s": f"{t_warm:.4f}",
        "warm_prefaults": prefaults, "warm_hits": warm_hits,
    })

    # -- transient read errors absorbed by retry ---------------------------
    idx2.drop_warm_cache()
    clean = idx2.query_batch(q)
    idx2.drop_warm_cache()
    idx2.store = FlakyStore(idx2.store, read_error_every=7)
    flaky = idx2.query_batch(q, io_retries=3, io_retry_backoff_s=1e-4)
    snap = idx2.pipeline_snapshot()
    for (i1, d1), (i2, d2) in zip(clean, flaky):
        o1, o2 = np.argsort(i1), np.argsort(i2)
        assert np.array_equal(i1[o1], i2[o2]), \
            "retry run returned different neighbor sets"
    rows.append({
        "name": "fig25/retry",
        "us_per_call": "",
        "io_read_errors": snap.get("io_read_errors", 0),
        "io_retries": snap.get("io_retries", 0),
    })
    idx2.close()
    flat.close()

    emit("fig25_resilience", rows)
    attach_stats(goodput=goodput, restore_s=info["restore_s"],
                 ckpt_overhead=overhead, warm_hits=warm_hits,
                 io_retries=snap.get("io_retries", 0))

    assert overhead < OVERHEAD_GATE, \
        f"checkpoint overhead {overhead:.1%} >= {OVERHEAD_GATE:.0%}"
    assert goodput >= GOODPUT_GATE, \
        f"goodput {goodput:.3f} under one kill < {GOODPUT_GATE}"
    shutil.rmtree(work, ignore_errors=True)


if __name__ == "__main__":
    main()
