"""Trace-enabled smoke (obs subsystem acceptance; CI runs this figure).

Two traced scenarios against one small built index:

  1. **batch self-join** with ``io_mode="prefetch"`` +
     ``compute_mode="device"`` under emulated SSD latency — export the
     span trace as Chrome-trace JSON, validate the schema, and assert
     the pipeline actually overlapped: reads coincided with the verify
     walk (``overlap_seconds("io.read", ("verify.*", "join.run")) > 0``)
     and the trace-derived ``hidden_fraction("io.read", "io.wait")``
     tracks ``PipelineStats.overlap_efficiency``.
  2. **scheduler wave** — concurrent requests through a
     ``QueryScheduler``; assert ``serve.wave`` spans exist and every
     completed request's ``serve.request`` async pair carries its wave id.

Emits one CSV row per scenario and attaches the trace-derived overlap
figures to the perf-trajectory record (``common.attach_stats``), so
``run.py --json-out`` captures the overlap trajectory per commit.
"""
from __future__ import annotations

import os
import tempfile

import numpy as np

from benchmarks.common import attach_stats, dataset, emit, scale
from repro.core import DiskJoinIndex, JoinConfig
from repro.obs import trace_session, validate_chrome_trace
from repro.serve import QueryScheduler
from repro.store.vector_store import FlatVectorStore

LATENCY_S = 5e-4   # emulated per-bucket read latency (NVMe-ish)


def main() -> None:
    n = scale(8000)
    x, eps = dataset(n, dim=32, avg_neighbors=10)
    workdir = tempfile.mkdtemp(prefix="obs_trace_")
    store = FlatVectorStore.from_array(os.path.join(workdir, "x.bin"), x)
    cfg = JoinConfig(epsilon=eps, recall_target=0.9, pad_align=64,
                     num_buckets=max(32, n // 100),
                     memory_budget_bytes=max(1 << 20, x.nbytes // 10),
                     io_mode="prefetch", io_threads=4,
                     compute_mode="device",
                     emulate_read_latency_s=LATENCY_S)
    index = DiskJoinIndex.build(store, cfg, os.path.join(workdir, "idx"))
    rows = []

    # -- 1. traced batch self-join: export, validate, overlap asserts ---------
    index.self_join(epsilon=eps)          # warm the verify-kernel jit
    index.drop_warm_cache()
    base = index.pipeline_snapshot()
    with trace_session() as tr:
        res = index.self_join(epsilon=eps)
    snap = index.pipeline_snapshot()
    trace_path = os.path.join(workdir, "join.trace.json")
    tr.export(trace_path)
    n_events = validate_chrome_trace(trace_path)
    an = tr.analysis()

    read_s = snap["read_s"] - base["read_s"]
    io_wait_s = snap["io_wait_s"] - base["io_wait_s"]
    overlap_eff = (max(0.0, read_s - io_wait_s) / read_s
                   if read_s > 0 else 1.0)
    hidden = an.hidden_fraction("io.read", "io.wait")
    read_verify_overlap_s = an.overlap_seconds(
        "io.read", ("verify.*", "join.run"))

    assert n_events > 0, "trace exported zero events"
    assert read_verify_overlap_s > 0, \
        "prefetch reads never overlapped the verify walk in the trace"
    assert {"io.read", "io.wait", "join.run", "verify.dispatch"} <= \
        set(an.names()), f"missing expected spans: {sorted(an.names())}"
    rows.append({
        "name": "obs_trace/self_join_prefetch_device",
        "us_per_call": "",
        "pairs": int(res.pairs.shape[0]),
        "trace_events": n_events,
        "read_hidden_fraction": f"{hidden:.3f}",
        "overlap_efficiency": f"{overlap_eff:.3f}",
        "read_verify_overlap_s": f"{read_verify_overlap_s:.4f}",
        "busy_wall_s":
            f"{sum(v for k, v in an.critical_path().items() if k != 'idle'):.4f}",
    })
    attach_stats(read_hidden_fraction=hidden,
                 overlap_efficiency=overlap_eff,
                 read_verify_overlap_s=read_verify_overlap_s,
                 trace_events=n_events)

    # -- 2. traced scheduler wave: spans + request↔wave linkage ---------------
    rng = np.random.default_rng(6)
    n_req = max(32, n // 32)
    queries = (x[rng.choice(n, n_req)]
               + rng.normal(scale=0.01, size=(n_req, x.shape[1]))
               ).astype(np.float32)
    with trace_session() as tr2:
        with QueryScheduler(index, wave_size=16, max_wait_s=0.002,
                            max_queue=4 * n_req) as sched:
            futs = [sched.submit(q) for q in queries]
            for f in futs:
                f.result(timeout=600)
    an2 = tr2.analysis()
    waves = an2.count("serve.wave")
    pairs = an2.async_pairs("serve.request")
    assert waves > 0, "no serve.wave spans recorded"
    assert len(pairs) == n_req, \
        f"{len(pairs)} serve.request pairs for {n_req} requests"
    assert all(p["args"].get("wave", 0) > 0 for p in pairs), \
        "a completed request's async end carries no wave id"
    rows.append({
        "name": "obs_trace/scheduler_wave",
        "us_per_call": "",
        "requests": n_req,
        "waves": waves,
        "request_p95_ms":
            f"{np.percentile([p['duration_s'] for p in pairs], 95) * 1e3:.2f}",
    })
    attach_stats(serve_waves=waves, serve_requests=len(pairs))

    emit("obs_trace", rows)
    print(f"# obs_trace summary: {n_events} events, "
          f"hidden={hidden:.3f} vs overlap_eff={overlap_eff:.3f}, "
          f"read∩verify={read_verify_overlap_s:.4f}s; "
          f"{waves} waves / {len(pairs)} traced requests")
    index.close()


if __name__ == "__main__":
    main()
