"""Fig. 19 (beyond-paper): prefetch pipeline sweep — io_mode × lookahead ×
pool size. Reports the executor's I/O stall (io_wait), the disk time the
pipeline hid (overlap efficiency), and queue/backpressure telemetry.

Expectation: sync mode is fully serial (io_wait == full read time by
construction); prefetch mode hides most read time behind verification
(io_wait << sync read time), improving with lookahead until the pool or
the schedule's miss spacing saturates.

Runs under emulated SSD access latency (``emulate_read_latency_s``):
page-cached memmap reads are RAM-speed in this container, which would hide
the very bottleneck the paper (and this subsystem) is about.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import attach_stats, dataset, emit, run_join, scale
from repro.obs import trace_session

LATENCY_S = 5e-4  # ~0.5 ms per bucket read — NVMe-ish random access


def main() -> None:
    n = scale(8000)
    x, eps = dataset(n, dim=32, avg_neighbors=10)
    rows = []

    def row(name, res, t, extra=None):
        io = res.io_stats
        r = {
            "name": name,
            "us_per_call": f"{t*1e6:.0f}",
            "total_s": f"{t:.3f}",
            "read_s": f"{io['read_seconds']:.4f}",
            "io_wait_s": f"{res.timings['io_wait']:.4f}",
            "compute_s": f"{res.timings['compute']:.4f}",
            "loads": res.bucket_loads,
        }
        if extra:
            r.update(extra)
        rows.append(r)

    run_join(x[:1000], eps, io_mode="sync")  # warm the verify-kernel jit

    # serial baseline: every miss stalls the verify loop
    res, t, _ = run_join(x, eps, io_mode="sync",
                         emulate_read_latency_s=LATENCY_S)
    sync_read_s = res.io_stats["read_seconds"]
    row("fig19/sync", res, t)

    for lookahead in (2, 8, 32):
        for pool in (None, 36):
            res, t, _ = run_join(x, eps, io_mode="prefetch",
                                 io_lookahead=lookahead, io_pool_slabs=pool,
                                 io_threads=4,
                                 emulate_read_latency_s=LATENCY_S)
            p = res.io_stats["pipeline"]
            row(f"fig19/prefetch_la{lookahead}_pool{pool or 'auto'}",
                res, t, {
                    "overlap_eff": f"{p['overlap_efficiency']:.3f}",
                    "pool_slabs": p["pool_slabs"],
                    "max_depth": p["max_queue_depth"],
                    "stalls": p["stalls"],
                    "backpressure": p["blocked_acquires"],
                    "hidden_vs_sync": f"{max(0.0, 1 - res.timings['io_wait']/max(sync_read_s, 1e-9)):.3f}",
                })

    # trace-enabled rerun of the best prefetch config: the span-derived
    # hidden fraction is the same quantity as overlap_efficiency measured
    # from the trace timeline instead of the stats accumulators
    with trace_session() as tr:
        res, t, _ = run_join(x, eps, io_mode="prefetch", io_lookahead=32,
                             io_threads=4,
                             emulate_read_latency_s=LATENCY_S)
    an = tr.analysis()
    hidden = an.hidden_fraction("io.read", "io.wait")
    p = res.io_stats["pipeline"]
    row("fig19/prefetch_la32_traced", res, t, {
        "overlap_eff": f"{p['overlap_efficiency']:.3f}",
        "trace_hidden_fraction": f"{hidden:.3f}",
        "trace_reads": an.count("io.read"),
    })
    attach_stats(read_hidden_fraction=hidden,
                 overlap_efficiency=p["overlap_efficiency"])

    emit("fig19", rows)
    # the acceptance gate of the pipeline: prefetch stalls < serial read time
    best_wait = min(float(r["io_wait_s"]) for r in rows
                    if r["name"].startswith("fig19/prefetch"))
    print(f"# fig19 summary: sync_read_s={sync_read_s:.4f} "
          f"best_prefetch_io_wait_s={best_wait:.4f} "
          f"overlap={'OK' if best_wait < sync_read_s else 'NONE'}")


if __name__ == "__main__":
    main()
