"""Fig. 11: execution time vs number of buckets (0.1‰–1% of N).
Paper claim: best around 1‰; too few ⇒ coarse partitioning, too many ⇒
sub-page buckets and read amplification."""
from __future__ import annotations

from benchmarks.common import dataset, emit, run_join, scale


def main() -> None:
    n = scale(20000)
    x, eps = dataset(n, dim=64, avg_neighbors=20)
    rows = []
    for frac_label, nb in (("0.5permille", max(4, n // 2000)),
                           ("1permille", max(8, n // 1000)),
                           ("5permille", max(16, n // 200)),
                           ("1percent", max(32, n // 100))):
        res, t, _ = run_join(x, eps, num_buckets=nb)
        rows.append({
            "name": f"fig11/diskjoin/buckets={frac_label}",
            "us_per_call": f"{t*1e6:.0f}",
            "seconds": f"{t:.2f}",
            "num_buckets": nb,
            "read_amplification":
                f"{res.io_stats['read_amplification']:.4f}",
            "cache_hit_rate": f"{res.cache_hit_rate:.3f}",
            "distance_computations": res.num_distance_computations,
        })
    emit("fig11", rows)


if __name__ == "__main__":
    main()
