"""Kernel micro-benchmark: pairwise-L2 verify throughput + roofline terms.

Wall-clock here is CPU (container); the roofline columns are the TPU-v5e
target numbers derived from the kernel's block structure: the (128,128,128)
tile does 2·128³ MACs on 3·128²·4 B of VMEM traffic — arithmetic intensity
128/3 FLOP/B ⇒ compute-bound on the MXU at bf16 (ridge at 240 FLOP/B needs
k-blocking ≥ … see EXPERIMENTS §Roofline for the kernel table)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, timed_us
from repro.kernels import ops

PEAK = 197e12
HBM = 819e9


def main() -> None:
    rng = np.random.default_rng(0)
    rows = []
    for m, n, d in ((512, 512, 64), (1024, 1024, 128), (2048, 2048, 128)):
        a = rng.normal(size=(m, d)).astype(np.float32)
        b = rng.normal(size=(n, d)).astype(np.float32)
        us, _ = timed_us(
            lambda: np.asarray(
                ops.pairwise_l2_threshold(a, b, 1.0, use_pallas=False)[0]),
            repeats=3)
        flops = 2.0 * m * n * d
        bytes_moved = 4.0 * (m * d + n * d + 2 * m * n)
        intensity = flops / bytes_moved
        rows.append({
            "name": f"kernel/pairwise_l2/{m}x{n}x{d}",
            "us_per_call": f"{us:.0f}",
            "gflops_cpu": f"{flops/us/1e3:.2f}",
            "arith_intensity": f"{intensity:.1f}",
            "tpu_compute_us": f"{flops/PEAK*1e6:.2f}",
            "tpu_memory_us": f"{bytes_moved/HBM*1e6:.2f}",
            "tpu_bound": "compute" if flops / PEAK > bytes_moved / HBM
                         else "memory",
        })

    for mb, bd in ((4096, 64), (8192, 128)):
        x = rng.normal(size=(mb, bd)).astype(np.float32)
        c = rng.normal(size=(256, bd)).astype(np.float32)
        us, _ = timed_us(
            lambda: np.asarray(ops.bucket_assign(x, c, use_pallas=False)[1]),
            repeats=3)
        rows.append({
            "name": f"kernel/bucket_assign/{mb}x256x{bd}",
            "us_per_call": f"{us:.0f}",
        })
    emit("kernel_roofline", rows)


if __name__ == "__main__":
    main()
