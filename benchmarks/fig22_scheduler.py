"""Fig. 22 (beyond-paper): wave-batched serving scheduler under concurrent
load — reads/query and p95 completion latency for sync / naive-batch /
wave-shared serving, at 1 and 4 shards.

The workload is the serving regime the paper's thesis predicts is
I/O-bound: ≥ 64 concurrent ε-range queries with heavily *overlapping*
candidate buckets (requests cluster around a few hot anchors), against a
store with emulated SSD read latency. All requests arrive at t=0; a
request's latency is its completion time from arrival (queueing included —
the number a user of the service actually experiences at this offered
load).

Serving policies compared (identical io_mode/prefetch settings — the
variable is the scheduling policy, not the I/O path):

  * ``sync``        — sequential ``VectorQueryService.query`` per request
                      (PR 3's facade: every caller pays its own reads);
  * ``naive_batch`` — ``QueryScheduler(share_probes=False)``: wave
                      admission, per-request execution — batching alone;
  * ``wave_shared`` — the full scheduler: each wave planned once, ONE read
                      per distinct bucket, slabs fanned out to every
                      member's verify;
  * ``wave_shared_4shards`` — ``IndexRouter`` over 4 shards, per-shard
                      wave scheduling, merged results.

The smoke assertions at the bottom are the regression guard for the
sharing path: ``reads_saved_by_sharing > 0`` and reads/query strictly
below the naive policy on this overlapping-probe workload.
"""
from __future__ import annotations

import os
import tempfile
import time

import numpy as np

from benchmarks.common import dataset, emit, scale
from repro.core import DiskJoinIndex, JoinConfig
from repro.serve import IndexRouter, QueryScheduler, VectorQueryService
from repro.store.vector_store import FlatVectorStore

LATENCY_S = 1e-3   # per bucket read — NVMe-ish random access
N_ANCHORS = 16     # hot spots the request stream clusters around


def _requests(x: np.ndarray, n_requests: int, rng) -> np.ndarray:
    """Concurrent request stream with overlapping candidate buckets:
    70% of queries hug one of a few hot anchors, 30% roam (the roamers
    churn the warm cache, so only wave-level sharing dedups the hot
    probes reliably)."""
    anchors = x[rng.choice(x.shape[0], N_ANCHORS, replace=False)]
    hot = anchors[rng.integers(0, N_ANCHORS, n_requests)]
    roam = x[rng.choice(x.shape[0], n_requests)]
    pick = rng.random(n_requests) < 0.7
    q = np.where(pick[:, None], hot, roam)
    return (q + rng.normal(scale=0.01, size=q.shape)).astype(np.float32)


def _spatial_split(x: np.ndarray, n_shards: int, rng) -> list[np.ndarray]:
    """Partition rows by nearest of ``n_shards`` coarse anchors — the
    spatially-coherent sharding a real deployment uses, which is what
    lets center-proximity routing skip shards."""
    anchors = x[rng.choice(x.shape[0], n_shards, replace=False)]
    d = ((x[:, None, :] - anchors[None, :, :]) ** 2).sum(-1)
    assign = d.argmin(1)
    return [x[assign == s] for s in range(n_shards)]


def _pcts(lat_s: np.ndarray) -> tuple[float, float]:
    return (float(np.percentile(lat_s, 50)) * 1e3,
            float(np.percentile(lat_s, 95)) * 1e3)


def _reads(snap: dict, base: dict) -> int:
    return sum(snap[k] - base[k] for k in
               ("query_reads", "query_fallback_reads"))


def _cfg(n: int, **kw) -> JoinConfig:
    # memory budget deliberately below the hot working set: the warm
    # slab cache alone cannot absorb the overlap, so read dedup has to
    # come from wave-level probe sharing (the thing being measured)
    base = dict(epsilon=0.0, recall_target=0.9, pad_align=64,
                num_buckets=max(48, n // 80),
                memory_budget_bytes=256 << 10,
                io_mode="prefetch", io_threads=4,
                emulate_read_latency_s=LATENCY_S)
    base.update(kw)
    return JoinConfig(**base)


def main() -> None:
    n = scale(8000)
    x, eps = dataset(n, dim=32, avg_neighbors=10)
    rng = np.random.default_rng(22)
    n_requests = max(64, n // 8)    # ≥ 64 concurrent (acceptance floor)
    queries = _requests(x, n_requests, rng)

    workdir = tempfile.mkdtemp(prefix="fig22_")
    store = FlatVectorStore.from_array(os.path.join(workdir, "x.bin"), x)
    index = DiskJoinIndex.build(store, _cfg(n, epsilon=eps),
                                os.path.join(workdir, "idx"))
    rows = []
    stats = {}

    # -- sync: sequential per-request serving (the PR 3 baseline) ------------
    index.drop_warm_cache()
    svc = VectorQueryService(index)
    base = index.pipeline_snapshot()
    t0 = time.perf_counter()
    done_t = np.empty(n_requests)
    for i, q in enumerate(queries):
        svc.query(q)
        done_t[i] = time.perf_counter() - t0    # completion since arrival
    total = time.perf_counter() - t0
    snap = index.pipeline_snapshot()
    p50, p95 = _pcts(done_t)
    stats["sync"] = dict(reads=_reads(snap, base), p95=p95)
    rows.append({
        "name": "fig22/sync_sequential",
        "us_per_call": f"{total / n_requests * 1e6:.0f}",
        "reads_per_query": f"{_reads(snap, base) / n_requests:.2f}",
        "p50_ms": f"{p50:.2f}", "p95_ms": f"{p95:.2f}",
        "qps": f"{n_requests / total:.0f}",
    })

    # -- scheduler policies: naive (no sharing) vs wave-shared ----------------
    for name, share in (("naive_batch", False), ("wave_shared", True)):
        index.drop_warm_cache()
        base = index.pipeline_snapshot()
        with QueryScheduler(index, wave_size=64, max_wait_s=0.002,
                            max_queue=4 * n_requests,
                            share_probes=share) as sched:
            t0 = time.perf_counter()
            futs = [sched.submit(q) for q in queries]
            for f in futs:
                f.result(timeout=600)
            total = time.perf_counter() - t0
            lat = np.asarray([f.latency_s for f in futs])
            ssnap = sched.snapshot()
        snap = index.pipeline_snapshot()
        p50, p95 = _pcts(lat)
        stats[name] = dict(reads=_reads(snap, base), p95=p95,
                           saved=snap["reads_saved_by_sharing"]
                           - base["reads_saved_by_sharing"])
        rows.append({
            "name": f"fig22/{name}",
            "us_per_call": f"{total / n_requests * 1e6:.0f}",
            "reads_per_query": f"{_reads(snap, base) / n_requests:.2f}",
            "p50_ms": f"{p50:.2f}", "p95_ms": f"{p95:.2f}",
            "qps": f"{n_requests / total:.0f}",
            "waves": ssnap["waves"],
            "wave_size_mean": f"{ssnap['wave']['size_mean']:.1f}",
            "reads_saved_by_sharing": stats[name]["saved"],
        })

    # -- 4-shard router: per-shard wave scheduling, merged results -----------
    shards = []
    for si, part in enumerate(_spatial_split(x, 4, rng)):
        pstore = FlatVectorStore.from_array(
            os.path.join(workdir, f"s{si}.bin"), part)
        shards.append(DiskJoinIndex.build(
            pstore, _cfg(part.shape[0], epsilon=eps),
            os.path.join(workdir, f"shard{si}")))
    router = IndexRouter(shards, scheduler=dict(
        wave_size=64, max_wait_s=0.002, max_queue=4 * n_requests))
    bases = [s.pipeline_snapshot() for s in shards]
    t0 = time.perf_counter()
    futs = [router.submit(q) for q in queries]
    lat = np.empty(n_requests)
    for i, f in enumerate(futs):
        f.result(timeout=600)
        lat[i] = f.latency_s
    total = time.perf_counter() - t0
    reads4 = sum(_reads(s.pipeline_snapshot(), b)
                 for s, b in zip(shards, bases))
    saved4 = sum(s.pipeline_snapshot()["reads_saved_by_sharing"]
                 - b["reads_saved_by_sharing"]
                 for s, b in zip(shards, bases))
    p50, p95 = _pcts(lat)
    rsnap = router.snapshot()
    rows.append({
        "name": "fig22/wave_shared_4shards",
        "us_per_call": f"{total / n_requests * 1e6:.0f}",
        "reads_per_query": f"{reads4 / n_requests:.2f}",
        "p50_ms": f"{p50:.2f}", "p95_ms": f"{p95:.2f}",
        "qps": f"{n_requests / total:.0f}",
        "fanout_mean": f"{rsnap['fanout_mean']:.2f}",
        "reads_saved_by_sharing": saved4,
    })
    emit("fig22", rows)

    # -- smoke regression guard (CI runs this figure) -------------------------
    shared, naive = stats["wave_shared"], stats["naive_batch"]
    assert shared["saved"] > 0, \
        "probe sharing saved zero reads on an overlapping workload"
    assert shared["reads"] < naive["reads"], \
        f"wave-shared reads {shared['reads']} not below naive {naive['reads']}"
    assert shared["p95"] < stats["sync"]["p95"], \
        f"wave-shared p95 {shared['p95']:.1f}ms not below sequential " \
        f"{stats['sync']['p95']:.1f}ms"
    print(f"# fig22 summary: {n_requests} concurrent requests — "
          f"reads/query sync={stats['sync']['reads'] / n_requests:.2f} "
          f"naive={naive['reads'] / n_requests:.2f} "
          f"shared={shared['reads'] / n_requests:.2f} "
          f"(saved {shared['saved']}); p95 "
          f"sync={stats['sync']['p95']:.1f}ms "
          f"shared={shared['p95']:.1f}ms; 4-shard reads/query="
          f"{reads4 / n_requests:.2f} (saved {saved4})")
    router.close()
    for s in shards:
        s.close()
    index.close()


if __name__ == "__main__":
    main()
