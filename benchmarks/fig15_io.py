"""Figs. 15+16: I/O vs compute time split and read-amplification table —
DiskJoin vs DiskANN-join. Paper claims: DiskANN ~70% time in I/O, amp 6–7×;
DiskJoin ≤21% I/O, amp ≈ 1.003."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import dataset, emit, make_store, run_join, scale
from repro.baselines.diskann_join import diskann_join


def main() -> None:
    n = scale(8000)
    x, eps = dataset(n, dim=32, avg_neighbors=10)
    rows = []

    res, t, store = run_join(x, eps)
    io = res.io_stats
    rows.append({
        "name": "fig15/diskjoin",
        "us_per_call": f"{t*1e6:.0f}",
        "total_s": f"{t:.2f}",
        "io_s": f"{io['read_seconds']:.3f}",
        "io_frac": f"{io['read_seconds']/max(t,1e-9):.3f}",
        "total_gb": f"{io['bytes_read_total']/1e9:.4f}",
        "useful_gb": f"{io['bytes_read_useful']/1e9:.4f}",
        "amplification": f"{io['read_amplification']:.4f}",
    })

    store2, _ = make_store(x)
    sample = np.random.default_rng(0).choice(n, size=max(64, n // 20),
                                             replace=False)
    t0 = time.perf_counter()
    diskann_join(store2, x, eps, sample_queries=sample)
    t_da = (time.perf_counter() - t0) * (n / len(sample))
    io2 = store2.stats
    rows.append({
        "name": "fig15/diskann",
        "us_per_call": f"{t_da*1e6:.0f}",
        "est_total_s": f"{t_da:.2f}",
        "io_s_sample": f"{io2.read_seconds:.3f}",
        "total_gb_sample": f"{io2.bytes_read_total/1e9:.3f}",
        "useful_gb_sample": f"{io2.bytes_read_useful/1e9:.3f}",
        "amplification": f"{io2.read_amplification:.2f}",
    })
    emit("fig15", rows)


if __name__ == "__main__":
    main()
