"""Randomness sensitivity (paper §6.3): repeat the join with different
center-sampling seeds; report recall/time mean ± std. Paper: recall
0.903 ± 0.005, time 276 ± 12.6 s on BigANN-10M — low variance."""
from __future__ import annotations

import numpy as np

from benchmarks.common import dataset, emit, run_join, scale
from repro.core import recall
from repro.data import brute_force_pairs


def main() -> None:
    n = scale(10000)
    x, eps = dataset(n, dim=32, avg_neighbors=10)
    truth = brute_force_pairs(x, eps)
    recalls, times = [], []
    repeats = 10
    for seed in range(repeats):
        res, t, _ = run_join(x, eps, seed=seed)
        recalls.append(recall(res.pairs, truth))
        times.append(t)
    emit("randomness", [{
        "name": "randomness/10_seeds",
        "us_per_call": f"{np.mean(times)*1e6:.0f}",
        "recall_mean": f"{np.mean(recalls):.4f}",
        "recall_std": f"{np.std(recalls):.4f}",
        "time_mean_s": f"{np.mean(times):.2f}",
        "time_std_s": f"{np.std(times):.2f}",
    }])


if __name__ == "__main__":
    main()
