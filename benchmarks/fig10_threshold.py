"""Fig. 10: execution time vs distance threshold ε (50–500 avg neighbors).
Paper claim: growth stays sublinear up to 500 neighbors."""
from __future__ import annotations

from benchmarks.common import dataset, emit, run_join, scale
from repro.data import clustered_vectors, epsilon_for_avg_neighbors


def main() -> None:
    n = scale(15000)
    x = clustered_vectors(n, 48, seed=2)
    rows = []
    for k in (50, 100, 200, 500):
        eps = epsilon_for_avg_neighbors(x, min(k, n - 1), seed=2)
        res, t, _ = run_join(x, eps)
        rows.append({
            "name": f"fig10/diskjoin/avg_neighbors={k}",
            "us_per_call": f"{t*1e6:.0f}",
            "seconds": f"{t:.2f}",
            "epsilon": f"{eps:.4f}",
            "pairs": res.pairs.shape[0],
            "distance_computations": res.num_distance_computations,
        })
    emit("fig10", rows)


if __name__ == "__main__":
    main()
