"""Fig. 12: phase breakdown — bucketing / orchestration / execution.
Paper claim: orchestration overhead ≈ 5% of total."""
from __future__ import annotations

from benchmarks.common import dataset, emit, run_join, scale


def main() -> None:
    n = scale(20000)
    x, eps = dataset(n, dim=64, avg_neighbors=20)
    res, t, _ = run_join(x, eps)
    tm = res.timings
    bucketing = tm.get("bucketing", 0.0)
    orch = tm.get("orchestration", 0.0)
    execu = tm.get("execute", 0.0)
    total = bucketing + orch + execu
    rows = [{
        "name": "fig12/breakdown",
        "us_per_call": f"{t*1e6:.0f}",
        "bucketing_s": f"{bucketing:.3f}",
        "orchestration_s": f"{orch:.3f}",
        "execution_s": f"{execu:.3f}",
        "orchestration_frac": f"{orch/max(total,1e-9):.3f}",
    }]
    emit("fig12", rows)


if __name__ == "__main__":
    main()
