"""Fig. 17: task-orchestration ablation — LRU / +Belady / +Reorder.
Paper claims: reorder ≈ +50% hit rate, Belady ≈ +20%; with both, >75% hit
rate at 10% memory and I/O stops being the bottleneck."""
from __future__ import annotations

from benchmarks.common import dataset, emit, run_join, scale


def main() -> None:
    n = scale(20000)
    x, eps = dataset(n, dim=64, avg_neighbors=20)
    mem = x.nbytes // 10
    variants = (
        ("lru", dict(eviction_policy="lru", reorder=False)),
        ("+belady", dict(eviction_policy="belady", reorder=False)),
        ("+reorder", dict(eviction_policy="belady", reorder=True)),
        # beyond-paper: metric-aware ordering (EXPERIMENTS §Perf/join)
        ("+spatial", dict(eviction_policy="belady", reorder=True,
                          order_strategy="spatial")),
    )
    base_time = None
    rows = []
    for label, kw in variants:
        res, t, _ = run_join(x, eps, memory_budget_bytes=mem, **kw)
        if label == "+reorder":
            base_time = t
    for label, kw in variants:
        res, t, _ = run_join(x, eps, memory_budget_bytes=mem, **kw)
        rows.append({
            "name": f"fig17/{label}",
            "us_per_call": f"{t*1e6:.0f}",
            "normalized_time": f"{t/max(base_time,1e-9):.2f}",
            "cache_hit_rate": f"{res.cache_hit_rate:.3f}",
            "bucket_loads": res.bucket_loads,
            "io_frac":
                f"{res.io_stats['read_seconds']/max(t,1e-9):.3f}",
        })
    emit("fig17", rows)


if __name__ == "__main__":
    main()
