"""Fig. 21 (beyond-paper): online point-query serving over a built
DiskJoinIndex — throughput/latency vs io_mode × lookahead, plus a batch
self-join running *concurrently* against the same BufferPool.

What it demonstrates (the session API's reason to exist):

  * the index is built ONCE; every scenario below — ε-joins and online
    queries alike — reuses the same bucketing and the same pool;
  * warm-cache effect: repeated point queries served from resident slabs
    (query_warm_hits) vs cold sweeps that hit the emulated SSD;
  * prefetch io_mode overlaps a query batch's candidate-bucket reads;
  * online traffic and a concurrent batch join appear in ONE
    PipelineStats snapshot (loads + query_reads side by side), sharing
    one slab budget without deadlock or result corruption.

Runs under emulated SSD access latency for the same reason as fig19/20.
"""
from __future__ import annotations

import os
import tempfile
import threading
import time

import numpy as np

from benchmarks.common import dataset, emit, scale
from repro.core import DiskJoinIndex, JoinConfig
from repro.serve import VectorQueryService
from repro.store.vector_store import FlatVectorStore

LATENCY_S = 2e-4  # per bucket read — NVMe-ish random access


def _percentiles(lat_s: list[float]) -> tuple[float, float]:
    a = np.asarray(lat_s, np.float64) * 1e3
    return float(np.percentile(a, 50)), float(np.percentile(a, 95))


def main() -> None:
    n = scale(8000)
    x, eps = dataset(n, dim=32, avg_neighbors=10)
    rng = np.random.default_rng(7)
    n_queries = max(50, scale(400))
    queries = (x[rng.choice(n, n_queries)]
               + rng.normal(scale=0.01, size=(n_queries, 32))
               ).astype(np.float32)

    workdir = tempfile.mkdtemp(prefix="fig21_")
    store = FlatVectorStore.from_array(os.path.join(workdir, "x.bin"), x)
    cfg = JoinConfig(epsilon=eps, recall_target=0.9, pad_align=64,
                     num_buckets=max(16, n // 100),
                     memory_budget_bytes=max(1 << 20, x.nbytes // 10),
                     io_threads=4, emulate_read_latency_s=LATENCY_S)
    t0 = time.perf_counter()
    index = DiskJoinIndex.build(store, cfg, os.path.join(workdir, "idx"))
    build_s = time.perf_counter() - t0
    rows = []

    # -- online-only scenarios: io_mode × lookahead, cold then warm ----------
    for io_mode, lookahead in (("sync", 0), ("prefetch", 4),
                               ("prefetch", 16)):
        index.drop_warm_cache()
        svc = VectorQueryService(index)
        kw = {"io_mode": io_mode}
        if lookahead:
            kw["io_lookahead"] = lookahead
        lat = []
        before = index.pipeline_snapshot()
        t0 = time.perf_counter()
        for q in queries:
            t1 = time.perf_counter()
            svc.query(q, **kw)
            lat.append(time.perf_counter() - t1)
        total = time.perf_counter() - t0
        p50, p95 = _percentiles(lat)
        snap = index.pipeline_snapshot()
        rows.append({
            "name": f"fig21/online_{io_mode}_la{lookahead or 'na'}",
            "us_per_call": f"{total / n_queries * 1e6:.0f}",
            "qps": f"{n_queries / total:.0f}",
            "p50_ms": f"{p50:.2f}", "p95_ms": f"{p95:.2f}",
            "warm_hits": snap["query_warm_hits"]
            - before["query_warm_hits"],
            "pooled_reads": snap["query_reads"] - before["query_reads"],
        })

    # warm repeat: the same queries again, served from resident slabs
    svc = VectorQueryService(index)
    before = index.pipeline_snapshot()
    t0 = time.perf_counter()
    for q in queries:
        svc.query(q)
    total = time.perf_counter() - t0
    after = index.pipeline_snapshot()
    rows.append({
        "name": "fig21/online_warm_repeat",
        "us_per_call": f"{total / n_queries * 1e6:.0f}",
        "qps": f"{n_queries / total:.0f}",
        "warm_hits": after["query_warm_hits"] - before["query_warm_hits"],
        "pooled_reads": after["query_reads"] - before["query_reads"],
    })

    # -- concurrent: batch ε-join + online queries on ONE pool/stats ---------
    index.drop_warm_cache()
    join_result = {}

    def run_join():
        join_result["res"] = index.self_join(io_mode="prefetch")

    svc = VectorQueryService(index)
    before = index.pipeline_snapshot()
    thread = threading.Thread(target=run_join)
    t0 = time.perf_counter()
    thread.start()
    lat = []
    served = 0
    while thread.is_alive():
        q = queries[served % n_queries]
        t1 = time.perf_counter()
        svc.query(q)
        lat.append(time.perf_counter() - t1)
        served += 1
    thread.join()
    total = time.perf_counter() - t0
    snap = index.pipeline_snapshot()  # ONE surface: join + online traffic
    p50, p95 = _percentiles(lat)
    rows.append({
        "name": "fig21/concurrent_join_plus_queries",
        "us_per_call": f"{total / max(1, served) * 1e6:.0f}",
        "queries_served": served,
        "p50_ms": f"{p50:.2f}", "p95_ms": f"{p95:.2f}",
        "join_s": f"{total:.3f}",
        "join_loads": snap["loads"] - before["loads"],
        "query_reads": snap["query_reads"] - before["query_reads"],
        "fallback_reads": snap["query_fallback_reads"],
        "join_pairs": join_result["res"].pairs.shape[0],
    })
    rows.append({
        "name": "fig21/build_amortized",
        "us_per_call": f"{build_s * 1e6:.0f}",
        "build_s": f"{build_s:.3f}",
        "note": "one build served every scenario above",
    })

    emit("fig21", rows)
    print(f"# fig21 summary: concurrent join + {served} online queries on "
          f"one pool; snapshot shows join_loads="
          f"{snap['loads'] - before['loads']} and query_reads="
          f"{snap['query_reads'] - before['query_reads']} together")
    index.close()


if __name__ == "__main__":
    main()
