"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (benchmarks/common.emit).
``REPRO_BENCH_SMALL=1`` runs each at 1/10 scale (CI smoke).
"""
from __future__ import annotations

import sys
import time
import traceback

from benchmarks import (fig7_baselines, fig8_recall, fig9_memory,
                        fig10_threshold, fig11_buckets, fig12_breakdown,
                        fig13_crossjoin, fig14_fragmentation, fig15_io,
                        fig17_ablation, fig18_pruning, fig19_pipeline,
                        fig20_striping, fig21_online, fig22_scheduler,
                        fig23_device_pipeline, kernel_roofline, randomness)

MODULES = [
    ("fig7_baselines", fig7_baselines),
    ("fig8_recall", fig8_recall),
    ("fig9_memory", fig9_memory),
    ("fig10_threshold", fig10_threshold),
    ("fig11_buckets", fig11_buckets),
    ("fig12_breakdown", fig12_breakdown),
    ("fig13_crossjoin", fig13_crossjoin),
    ("fig14_fragmentation", fig14_fragmentation),
    ("fig15_io", fig15_io),
    ("fig17_ablation", fig17_ablation),
    ("fig18_pruning", fig18_pruning),
    ("fig19_pipeline", fig19_pipeline),
    ("fig20_striping", fig20_striping),
    ("fig21_online", fig21_online),
    ("fig22_scheduler", fig22_scheduler),
    ("fig23_device_pipeline", fig23_device_pipeline),
    ("randomness", randomness),
    ("kernel_roofline", kernel_roofline),
]


def main() -> None:
    only = sys.argv[1] if len(sys.argv) > 1 else None
    failures = []
    for name, mod in MODULES:
        if only and only not in name:
            continue
        t0 = time.perf_counter()
        print(f"# === {name} ===", flush=True)
        try:
            mod.main()
        except Exception:
            failures.append(name)
            traceback.print_exc()
        print(f"# {name} done in {time.perf_counter()-t0:.1f}s", flush=True)
    if failures:
        print(f"# FAILED: {failures}")
        sys.exit(1)


if __name__ == "__main__":
    main()
