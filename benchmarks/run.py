"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (benchmarks/common.emit).
``REPRO_BENCH_SMALL=1`` runs each at 1/10 scale (CI smoke).

``--json-out DIR`` additionally writes one ``BENCH_<figure>.json`` per
executed module — the emitted rows, any trace-derived stats the module
attached (``common.attach_stats``), the config fingerprint, elapsed wall
time and pass/fail status. CI archives these per commit: the perf
trajectory of the repo, one point per figure per revision.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
import traceback

import numpy as np

from benchmarks import (common, fig7_baselines, fig8_recall, fig9_memory,
                        fig10_threshold, fig11_buckets, fig12_breakdown,
                        fig13_crossjoin, fig14_fragmentation, fig15_io,
                        fig17_ablation, fig18_pruning, fig19_pipeline,
                        fig20_striping, fig21_online, fig22_scheduler,
                        fig23_device_pipeline, fig24_planner,
                        fig25_resilience, fig26_live, fig27_replication,
                        kernel_roofline,
                        obs_trace, randomness)

MODULES = [
    ("fig7_baselines", fig7_baselines),
    ("fig8_recall", fig8_recall),
    ("fig9_memory", fig9_memory),
    ("fig10_threshold", fig10_threshold),
    ("fig11_buckets", fig11_buckets),
    ("fig12_breakdown", fig12_breakdown),
    ("fig13_crossjoin", fig13_crossjoin),
    ("fig14_fragmentation", fig14_fragmentation),
    ("fig15_io", fig15_io),
    ("fig17_ablation", fig17_ablation),
    ("fig18_pruning", fig18_pruning),
    ("fig19_pipeline", fig19_pipeline),
    ("fig20_striping", fig20_striping),
    ("fig21_online", fig21_online),
    ("fig22_scheduler", fig22_scheduler),
    ("fig23_device_pipeline", fig23_device_pipeline),
    ("fig24_planner", fig24_planner),
    ("fig25_resilience", fig25_resilience),
    ("fig26_live", fig26_live),
    ("fig27_replication", fig27_replication),
    ("obs_trace", obs_trace),
    ("randomness", randomness),
    ("kernel_roofline", kernel_roofline),
]


def _json_default(o):
    if isinstance(o, (np.integer,)):
        return int(o)
    if isinstance(o, (np.floating,)):
        return float(o)
    if isinstance(o, np.ndarray):
        return o.tolist()
    return str(o)


def _git_sha() -> str | None:
    """Commit the record was produced at, best-effort (regress.py prints
    it in diffs; records from exported tarballs just omit it)."""
    import subprocess
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            cwd=os.path.dirname(os.path.abspath(__file__)), timeout=10)
    except (OSError, subprocess.TimeoutExpired):
        return None
    sha = out.stdout.strip()
    return sha if out.returncode == 0 and sha else None


def _write_record(json_out: str, name: str, *, rows, stats, elapsed,
                  status, fingerprint) -> str:
    rec = {
        "figure": name,
        "status": status,
        "elapsed_s": elapsed,
        "wall_s": elapsed,
        "seed": common.BENCH_SEED,
        "git_sha": _git_sha(),
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
        "fingerprint": fingerprint,
        "rows": rows,
        "trace_stats": stats,
    }
    path = os.path.join(json_out, f"BENCH_{name}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=2, default=_json_default)
    return path


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("only", nargs="?", default=None,
                    help="substring filter on module names")
    ap.add_argument("--json-out", metavar="DIR", default=None,
                    help="write per-figure BENCH_<figure>.json records "
                         "into DIR (perf-trajectory pipeline)")
    args = ap.parse_args()

    fingerprint = None
    if args.json_out:
        os.makedirs(args.json_out, exist_ok=True)
        fingerprint = common.config_fingerprint()

    failures = []
    for name, mod in MODULES:
        if args.only and args.only not in name:
            continue
        t0 = time.perf_counter()
        print(f"# === {name} ===", flush=True)
        common.set_figure(name)
        status = "ok"
        try:
            mod.main()
        except Exception:
            failures.append(name)
            status = "error"
            traceback.print_exc()
        elapsed = time.perf_counter() - t0
        print(f"# {name} done in {elapsed:.1f}s", flush=True)
        if args.json_out:
            path = _write_record(
                args.json_out, name,
                rows=common.COLLECTED.get(name, []),
                stats=common.TRACE_STATS.get(name, {}),
                elapsed=elapsed, status=status, fingerprint=fingerprint)
            print(f"# wrote {path}", flush=True)
    if failures:
        print(f"# FAILED: {failures}")
        sys.exit(1)


if __name__ == "__main__":
    main()
