"""Fig. 13: cross-join — reorder-larger (DiskJoin1) vs reorder-smaller
(DiskJoin2). Paper claim: DiskJoin1 slightly faster (less disk traffic)."""
from __future__ import annotations

import os
import tempfile
import time

import numpy as np

from benchmarks.common import emit, scale
from repro.core import JoinConfig, similarity_cross_join
from repro.data import clustered_vectors
from repro.store.vector_store import FlatVectorStore


def main() -> None:
    nx, ny = scale(12000), scale(8000)
    x = clustered_vectors(nx, 32, seed=2)
    y = clustered_vectors(ny, 32, seed=3, clusters=32)
    y[:ny // 2] = x[:ny // 2] + np.random.default_rng(0).normal(
        scale=0.02, size=(ny // 2, 32)).astype(np.float32)
    rows = []
    for label, reorder_larger in (("diskjoin1", True), ("diskjoin2", False)):
        d = tempfile.mkdtemp()
        sx = FlatVectorStore.from_array(os.path.join(d, "x.bin"), x)
        sy = FlatVectorStore.from_array(os.path.join(d, "y.bin"), y)
        cfg = JoinConfig(epsilon=0.35, recall_target=0.9, pad_align=64,
                         memory_budget_bytes=max(1 << 20, x.nbytes // 10),
                         num_buckets=max(16, nx // 300))
        t0 = time.perf_counter()
        res = similarity_cross_join(sx, sy, cfg, workdir=d,
                                    reorder_larger=reorder_larger)
        t = time.perf_counter() - t0
        rows.append({
            "name": f"fig13/{label}",
            "us_per_call": f"{t*1e6:.0f}",
            "seconds": f"{t:.2f}",
            "pairs": res.pairs.shape[0],
            "disk_gb": f"{res.io_stats['bytes_read_total']/1e9:.4f}",
            "cache_hit_rate": f"{res.cache_hit_rate:.3f}",
        })
    emit("fig13", rows)


if __name__ == "__main__":
    main()
