"""Fig. 18: probabilistic pruning ablation — candidates + time across the
recall budget. Paper claims 10–50× candidate cuts on billion-scale real
embeddings; at laptop scale on synthetic manifolds the Eq. 1 prefilter is
strong, so the magnitude is smaller — the mechanism (monotone candidate
reduction with recall ≥ λ) is fully exercised (see DESIGN §9)."""
from __future__ import annotations

from benchmarks.common import emit, run_join, scale
from repro.core import recall
from repro.data import brute_force_pairs, clustered_vectors, \
    epsilon_for_avg_neighbors


def main() -> None:
    n = scale(12000)
    x = clustered_vectors(n, 96, seed=5, cluster_std_range=(0.03, 0.9),
                          intrinsic_dim=12, clusters=max(8, n // 300))
    eps = epsilon_for_avg_neighbors(x, 20, seed=2)
    truth = brute_force_pairs(x, eps) if n <= 20000 else None
    rows = []
    variants = [("wo_pruning", dict(prune=False)),
                ("w_pruning/lam=0.99", dict(prune=True, recall_target=0.99)),
                ("w_pruning/lam=0.9", dict(prune=True, recall_target=0.9)),
                ("w_pruning/lam=0.7", dict(prune=True, recall_target=0.7))]
    for label, kw in variants:
        res, t, _ = run_join(x, eps, num_buckets=max(32, n // 100),
                             max_candidates=99, **kw)
        rows.append({
            "name": f"fig18/{label}",
            "us_per_call": f"{t*1e6:.0f}",
            "seconds": f"{t:.2f}",
            "candidates": res.num_candidate_pairs,
            "recall": (f"{recall(res.pairs, truth):.4f}"
                       if truth is not None else "n/a"),
        })
    emit("fig18", rows)


if __name__ == "__main__":
    main()
