"""Fig. 14: file-system fragmentation stress. Paper claim: robust under
moderate fragmentation (<10% slowdown even at 16 KB extents) because SSDs
don't seek — degradation appears only when extents shrink toward the 4 KB
page and re-introduce read amplification. We emulate extents at the store
layer and report the amplification curve."""
from __future__ import annotations

import os

from benchmarks.common import dataset, emit, make_store, scale
from repro.core import JoinConfig, bucketize, build_bucket_graph
from repro.core.executor import JoinExecutor
from repro.store.vector_store import BucketedVectorStore


def main() -> None:
    n = scale(10000)
    x, eps = dataset(n, dim=64, avg_neighbors=20)
    store, workdir = make_store(x)
    cfg = JoinConfig(epsilon=eps, recall_target=0.9, pad_align=64,
                     memory_budget_bytes=max(1 << 20, x.nbytes // 10),
                     num_buckets=max(16, n // 100))
    bstore, meta, _ = bucketize(store, os.path.join(workdir, "bk"), cfg)
    graph = build_bucket_graph(meta, cfg)

    rows = []
    # extent sizes in rows (256 B rows): page-multiple extents are free;
    # sub-page extents (2 KB / 1 KB) re-introduce amplification
    for label, frag in (("none", None), ("1024KB", 4096), ("128KB", 512),
                        ("16KB", 64), ("2KB", 8), ("1KB", 4)):
        fstore = BucketedVectorStore(os.path.join(workdir, "bk"),
                                     fragment_rows=frag)
        res = JoinExecutor(fstore, meta, cfg).run(graph)
        rows.append({
            "name": f"fig14/fragmentation={label}",
            "us_per_call": "",
            "extents_per_bucket": (1 if frag is None else
                                   max(1, int(meta.sizes.mean()) // frag + 1)),
            "read_amplification":
                f"{res.io_stats['read_amplification']:.4f}",
            "disk_gb": f"{res.io_stats['bytes_read_total']/1e9:.4f}",
        })
    emit("fig14", rows)


if __name__ == "__main__":
    main()
