"""Fig. 23 (beyond-paper): device-resident verify pipeline — compute_mode
× io_mode × emulated host↔device link.

The stacked pipeline picture: PR 1 hid SSD *reads* behind verification
(io_mode="prefetch"); this figure adds the next hop — compute_mode=
"device" hides *staging* too. Bucket slabs cross H2D once per cache
residency (``h2d_transfers`` bounded by residencies, not edges), dispatch
is double-buffered (the next batch's staging walk overlaps the in-flight
kernel, ``d2h_overlap_s``), and the kernel returns compacted
(row, col, distance) triples instead of (E, cap, cap) masks — see the
``h2d_mb``/``d2h_mb`` columns for the structural win: the device path
moves ~7× fewer bytes across the link in each direction.

Link emulation (``emulate_xfer_gb_s``): on this container "host" and
"device" share one memory, so staging costs no wall time and the device
path's extra on-device compaction shows as pure overhead. The ``link``
rows restore the accelerator-attached regime the same way fig19's
emulated SSD latency restores the disk-bound regime: transfer volume is
charged at a fixed link bandwidth, and the verify wall time flips in
favor of the device-resident pipeline because it simply moves far fewer
bytes.

CI gates (REPRO_BENCH_SMALL=1): device/host pair+distance parity is
byte-identical, ``h2d_transfers_saved`` > 0, and device ``h2d_bytes``
strictly below the host per-edge staging baseline. At full scale the
summary additionally reports the link-regime verify wall-time win.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import dataset, emit, run_join, scale

LATENCY_S = 2e-4   # light SSD latency: reads hidden, verify is the frontier
XFER_GB_S = 0.5    # modeled constrained accelerator link (PCIe-share/fabric)
REPS = 2           # first rep pays jit compilation; report the warm rep


def main() -> None:
    n = scale(8000)
    x, eps = dataset(n, dim=96, avg_neighbors=10)
    rows = []
    results = {}

    grid = [
        ("host_sync", dict(compute_mode="host", io_mode="sync")),
        ("host_prefetch", dict(compute_mode="host", io_mode="prefetch")),
        ("device_prefetch", dict(compute_mode="device",
                                 io_mode="prefetch")),
        ("host_link", dict(compute_mode="host", io_mode="prefetch",
                           emulate_xfer_gb_s=XFER_GB_S)),
        ("device_link", dict(compute_mode="device", io_mode="prefetch",
                             emulate_xfer_gb_s=XFER_GB_S)),
    ]
    for name, cfg in grid:
        for rep in range(REPS):
            res, t, _ = run_join(x, eps, io_threads=4,
                                 num_buckets=max(16, n // 130),
                                 emulate_read_latency_s=LATENCY_S, **cfg)
        pipe = res.io_stats.get("pipeline", {})
        rows.append({
            "name": f"fig23/{name}",
            "us_per_call": f"{t*1e6:.0f}",
            "total_s": f"{t:.3f}",
            "compute_s": f"{res.timings['compute']:.4f}",
            "io_wait_s": f"{res.timings['io_wait']:.4f}",
            "h2d_transfers": pipe.get("h2d_transfers", 0),
            "h2d_mb": f"{pipe.get('h2d_bytes', 0) / 1e6:.2f}",
            "d2h_mb": f"{pipe.get('d2h_bytes', 0) / 1e6:.2f}",
            "h2d_saved": pipe.get("h2d_transfers_saved", 0),
            "slab_hits": pipe.get("device_slab_hits", 0),
            "d2h_overlap_s": f"{pipe.get('d2h_overlap_s', 0.0):.4f}",
            "overflows": pipe.get("device_compact_overflows", 0),
        })
        results[name] = res

    emit("fig23", rows)

    # -- acceptance gates -----------------------------------------------------
    rh, rd = results["host_prefetch"], results["device_prefetch"]
    assert np.array_equal(rh.pairs, rd.pairs), "device/host pair mismatch"
    assert np.array_equal(rh.distances, rd.distances), \
        "device/host distance mismatch"
    ph = rh.io_stats["pipeline"]
    pd = rd.io_stats["pipeline"]
    assert pd["h2d_transfers_saved"] > 0, "no operand staging was shared"
    assert pd["h2d_bytes"] < ph["h2d_bytes"], (
        f"device h2d {pd['h2d_bytes']} not below per-edge staging "
        f"baseline {ph['h2d_bytes']}")
    link_h = float(results["host_link"].timings["compute"])
    link_d = float(results["device_link"].timings["compute"])
    print(f"# fig23 summary: parity=OK "
          f"h2d_mb host={ph['h2d_bytes']/1e6:.1f} "
          f"device={pd['h2d_bytes']/1e6:.1f} "
          f"d2h_mb host={ph['d2h_bytes']/1e6:.1f} "
          f"device={pd['d2h_bytes']/1e6:.1f} "
          f"transfers_saved={pd['h2d_transfers_saved']} "
          f"link_verify_s host={link_h:.3f} device={link_d:.3f} "
          f"({link_h/max(link_d, 1e-9):.2f}x)")


if __name__ == "__main__":
    main()
