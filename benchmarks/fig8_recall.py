"""Fig. 8: execution time vs target recall λ (DiskJoin vs DiskANN-join).
Paper claim: 52×–1137× speedup; DiskANN time grows faster with recall."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import dataset, emit, make_store, run_join, scale
from repro.baselines.diskann_join import build_index, diskann_join, search_eps
from repro.core import recall
from repro.data import brute_force_pairs


def main() -> None:
    n = scale(10000)
    x, eps = dataset(n, dim=32, avg_neighbors=10)
    truth = brute_force_pairs(x, eps)
    rows = []
    for lam in (0.8, 0.9, 0.95, 0.99):
        res, t, store = run_join(x, eps, recall_target=lam)
        rows.append({
            "name": f"fig8/diskjoin/recall={lam}",
            "us_per_call": f"{t*1e6:.0f}",
            "seconds": f"{t:.2f}",
            "achieved_recall": f"{recall(res.pairs, truth):.4f}",
            "disk_gb": f"{res.io_stats['bytes_read_total']/1e9:.3f}",
        })

    # DiskANN baseline: time estimated from a query sample (paper protocol —
    # "we randomly sample 1‰ of the vectors"), here 2% for tighter CI.
    store, _ = make_store(x)
    sample = np.random.default_rng(0).choice(n, size=max(64, n // 50),
                                             replace=False)
    for beam in (16, 48):
        t0 = time.perf_counter()
        _, dc = diskann_join(store, x, eps, beam=beam,
                             sample_queries=sample)
        t_sample = time.perf_counter() - t0
        est_total = t_sample * (n / len(sample))
        rows.append({
            "name": f"fig8/diskann/beam={beam}",
            "us_per_call": f"{est_total*1e6:.0f}",
            "est_total_seconds": f"{est_total:.2f}",
            "sampled_queries": len(sample),
            "disk_gb_sample": f"{store.stats.bytes_read_total/1e9:.3f}",
            "read_amplification": f"{store.stats.read_amplification:.1f}",
        })
    emit("fig8", rows)


if __name__ == "__main__":
    main()
