"""Fig. 9: execution time vs memory budget (5%–20% of dataset).
Paper claim: diminishing returns beyond 10% — compute, not I/O, dominates."""
from __future__ import annotations

from benchmarks.common import dataset, emit, run_join, scale
from repro.core import recall
from repro.data import brute_force_pairs


def main() -> None:
    n = scale(20000)
    x, eps = dataset(n, dim=64, avg_neighbors=20)
    rows = []
    for frac in (0.05, 0.10, 0.20):
        res, t, _ = run_join(x, eps,
                             memory_budget_bytes=int(x.nbytes * frac))
        io_s = res.io_stats["read_seconds"]
        rows.append({
            "name": f"fig9/diskjoin/mem={int(frac*100)}%",
            "us_per_call": f"{t*1e6:.0f}",
            "seconds": f"{t:.2f}",
            "cache_hit_rate": f"{res.cache_hit_rate:.3f}",
            "bucket_loads": res.bucket_loads,
            "io_seconds": f"{io_s:.3f}",
            "io_fraction": f"{io_s/max(t,1e-9):.3f}",
        })
    emit("fig9", rows)


if __name__ == "__main__":
    main()
