"""Fig. 27 (beyond-paper): replicated, self-healing serving — replica
failover, supervised restart, degraded-mode coverage.

PR 8's crash-safe serving restarts a *process*; a single-copy shard
still takes every in-flight and future request down with it. The
``serve.replica`` tier (``ReplicaSet``/``HealthTracker``/
``ReplicaSupervisor``) keeps serving through replica death: health-gated
routing ejects the dead copy, failover retries on a sibling with the
request's remaining deadline, a supervisor reopens the dead session off
the request path, and a router fan-out missing a whole shard can return
partial results that say so (``Coverage``).

Four sections, all at emulated SSD latency:

  * **parity** — a 2-replica-per-shard router must answer byte-identically
    to a single-copy router over the same shard manifests.
  * **failover** — kill one replica (store dies + warm cache lost)
    mid-load. Goodput = answered / submitted must stay >= 0.95 with zero
    lost or duplicate results (every answer checked against brute force),
    and the failover-phase p95 latency stays bounded.
  * **restart** — a killed replica is detected DOWN, reopened via
    ``DiskJoinIndex.reopen`` (warm start), probed, re-admitted; the
    restarted replica must serve byte-correct results again.
  * **coverage** — with EVERY replica of one shard down, strict mode
    refuses; ``require_full_coverage=False`` returns the surviving
    shards' results with an honest per-shard coverage report.

CI gates (REPRO_BENCH_SMALL=1): byte-parity replicated vs single-copy,
one-kill goodput >= 0.95 with zero lost/duplicate, failover p95 below a
generous smoke-scale bound, restarts >= 1 with post-restart parity,
partial coverage accounting exact.
"""
from __future__ import annotations

import os
import shutil
import tempfile
import time

import numpy as np

from benchmarks.common import SMALL, attach_stats, dataset, emit, scale
from repro.core import DiskJoinIndex, JoinConfig
from repro.ft import FaultInjector
from repro.serve import (DOWN, HEALTHY, IndexRouter, ReplicaSet,
                         ReplicaSupervisor, ShardUnavailable)
from repro.store.vector_store import FlatVectorStore

LATENCY_S = 2e-3 if SMALL else 5e-4
GOODPUT_GATE = 0.95
# generous absolute bound at smoke scale: a failover pays one wasted
# attempt + one full retry, each a handful of emulated reads — seconds
# would mean retry storms or a stuck pick loop, which is what the gate
# is for
FAILOVER_P95_GATE_S = 5.0
KILL_AT_FRACTION = 0.3   # kill ~30% into the failover query stream


def _build_shards(x, eps, work):
    half = len(x) // 2
    parts = [x[:half], x[half:]]
    cfg = JoinConfig(epsilon=eps, recall_target=0.9, pad_align=64,
                     num_buckets=max(16, len(x) // 250),
                     memory_budget_bytes=max(256 << 10, x.nbytes // 8),
                     emulate_read_latency_s=LATENCY_S)
    dirs = []
    for i, part in enumerate(parts):
        flat = FlatVectorStore.from_array(
            os.path.join(work, f"x{i}.bin"), part)
        DiskJoinIndex.build(flat, cfg, os.path.join(work, f"s{i}")).close()
        dirs.append(os.path.join(work, f"s{i}"))
    return dirs, parts


def _truth(part, q, eps):
    return set(np.where(
        np.linalg.norm(part - q[None, :], axis=1) <= eps)[0].tolist())


def main() -> None:
    n = scale(6000)
    x, eps = dataset(n, dim=32, avg_neighbors=10)
    work = tempfile.mkdtemp(prefix="fig27_")
    dirs, parts = _build_shards(x, eps, work)
    queries = x[:: max(1, n // 48)][:48] + 1e-3
    rows = []

    # -- parity: replicated router vs single-copy --------------------------
    single = IndexRouter([DiskJoinIndex.open(d) for d in dirs],
                         epsilon=eps, close_shards=True,
                         scheduler=dict(max_wait_s=0.001))
    repl = IndexRouter([[DiskJoinIndex.open(d), DiskJoinIndex.open(d)]
                        for d in dirs], epsilon=eps, close_shards=True,
                       scheduler=dict(max_wait_s=0.001))
    t0 = time.perf_counter()
    mismatches = 0
    for q in queries:
        i1, d1 = single.query(q, timeout=300)
        i2, d2 = repl.query(q, timeout=300)
        if not (np.array_equal(i1, i2) and np.array_equal(d1, d2)):
            mismatches += 1
    parity_s = time.perf_counter() - t0
    single.close()
    repl.close()
    assert mismatches == 0, \
        f"replicated router diverged from single-copy on {mismatches} queries"
    rows.append({
        "name": "fig27/parity",
        "us_per_call": f"{parity_s / max(1, len(queries)) * 1e6:.0f}",
        "queries": len(queries), "mismatches": mismatches,
    })

    # -- failover: one replica killed mid-load -----------------------------
    rset = ReplicaSet([DiskJoinIndex.open(dirs[0]) for _ in range(2)],
                      epsilon=eps, scheduler=dict(max_wait_s=0.001),
                      name="shard0")
    kill_at = max(1, int(len(queries) * KILL_AT_FRACTION))
    inj = FaultInjector()
    answered = lost = dup = 0
    post_kill_lat = []
    for qi, q in enumerate(queries):
        if qi == kill_at:
            inj.kill_replica(rset.replicas[0])
            # drop the warm-up EWMAs: they measure which replica paid
            # the cold OS-cache reads, and that skew can park the dead
            # replica outside the near-equal rotation so it is never
            # probed — the fallback (queue depth + round-robin) routing
            # guarantees the kill surfaces deterministically
            for r in rset.replicas:
                r.service_ewma = None
                r.predicted_s = None
        fut = rset.submit(q)
        ids, _ = fut.result(timeout=300)
        expect = _truth(parts[0], q, eps)
        got = ids.tolist()
        if len(got) != len(set(got)):
            dup += 1
        elif set(got) != expect:
            lost += 1
        else:
            answered += 1
        if qi >= kill_at:
            post_kill_lat.append(fut.latency_s)
    goodput = answered / len(queries)
    p95 = float(np.percentile(post_kill_lat, 95))
    snap = rset.snapshot()
    assert snap["counters"]["failovers"] >= 1, \
        "kill_replica never triggered a failover"
    assert snap["replicas"][0]["health"]["state"] == DOWN, \
        "killed replica was not ejected"
    rows.append({
        "name": "fig27/failover",
        "us_per_call": f"{p95*1e6:.0f}",
        "goodput": f"{goodput:.3f}", "lost": lost, "duplicate": dup,
        "failovers": snap["counters"]["failovers"],
        "p95_after_kill_ms": f"{p95*1e3:.2f}",
    })

    # -- supervised restart: the dead replica comes back -------------------
    sup = ReplicaSupervisor(rset, poll_s=0.05, backoff_s=0.1,
                            probe_timeout_s=300.0)
    t0 = time.perf_counter()
    restarted = sup.poll_once()
    restart_s = time.perf_counter() - t0
    assert restarted >= 1 and sup.restarts >= 1, \
        "supervisor did not restart the DOWN replica"
    assert rset.replicas[0].health.state == HEALTHY, \
        "restarted replica was not re-admitted healthy"
    post_mismatch = 0
    for q in queries[:12]:
        ids, _ = rset.replicas[0].scheduler.query(q, timeout=300)
        if set(ids.tolist()) != _truth(parts[0], q, eps):
            post_mismatch += 1
    assert post_mismatch == 0, \
        f"restarted replica diverged on {post_mismatch} queries"
    sup.close()
    rset.close(close_indexes=True)
    rows.append({
        "name": "fig27/restart",
        "us_per_call": f"{restart_s*1e6:.0f}",
        "restarts": sup.restarts, "restart_s": f"{restart_s:.3f}",
        "post_restart_mismatches": post_mismatch,
    })

    # -- degraded-mode coverage: a whole shard down ------------------------
    router = IndexRouter([[DiskJoinIndex.open(dirs[0])],
                          [DiskJoinIndex.open(dirs[1])]], epsilon=eps,
                         close_shards=True,
                         scheduler=dict(max_wait_s=0.001))
    for r in router.replica_sets[1].replicas:
        inj.kill_replica(r)
        r.health.mark_down("fig27 coverage section")
    wide_eps = float(np.linalg.norm(x.max(0) - x.min(0)))  # spans shards
    strict_refused = False
    try:
        router.query(queries[0], epsilon=wide_eps, timeout=300)
    except ShardUnavailable:
        strict_refused = True
    assert strict_refused, "strict mode answered despite a dead shard"
    partial_ok = 0
    for q in queries[:12]:
        fut = router.submit(q, epsilon=wide_eps,
                            require_full_coverage=False)
        ids, _ = fut.result(timeout=300)
        cov = fut.coverage
        if (cov is not None and not cov.complete and cov.answered == 1
                and cov.total == 2
                and set(ids.tolist()) == _truth(parts[0], q, wide_eps)):
            partial_ok += 1
    router.close()
    assert partial_ok == 12, \
        f"only {partial_ok}/12 partial results carried exact coverage"
    rows.append({
        "name": "fig27/coverage",
        "us_per_call": "",
        "partial_ok": partial_ok, "strict_refused": int(strict_refused),
    })

    emit("fig27_replication", rows)
    attach_stats(goodput=goodput, failover_p95_s=p95,
                 replica_mismatches=mismatches, restarts=sup.restarts,
                 coverage_exact_fraction=partial_ok / 12.0)

    assert goodput >= GOODPUT_GATE, \
        f"goodput {goodput:.3f} under one replica kill < {GOODPUT_GATE}"
    assert lost == 0 and dup == 0, \
        f"failover lost {lost} / duplicated {dup} results"
    assert p95 < FAILOVER_P95_GATE_S, \
        f"failover p95 {p95:.2f}s >= {FAILOVER_P95_GATE_S}s"
    shutil.rmtree(work, ignore_errors=True)


if __name__ == "__main__":
    main()
